"""Benchmark harness: one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig9]``
prints ``name,us_per_call,derived`` CSV lines (plus a header).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (ablation_partitioner, bench_build,
                        fig5_access_rate, fig6_precision, fig7_throughput,
                        fig8_latency, fig9_comparison, fig10_mips,
                        fig11_scalability, fig12_straggler, fig13_failure,
                        roofline)

SUITES = {
    "build": bench_build.run,
    "fig5": fig5_access_rate.run,
    "fig6": fig6_precision.run,
    "fig7": fig7_throughput.run,
    "fig8": fig8_latency.run,
    "fig9": fig9_comparison.run,
    "fig10": fig10_mips.run,
    "fig11": fig11_scalability.run,
    "fig12": fig12_straggler.run,
    "fig13": fig13_failure.run,
    "ablation": ablation_partitioner.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small datasets (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SUITES))
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        t0 = time.time()
        try:
            SUITES[name](quick=args.quick)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
