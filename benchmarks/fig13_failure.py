"""Paper Fig. 13: throughput timeline across an executor failure and
rejoin. Expectation: dip at failure, full recovery (no lost queries), and
the monitor restarts the executor."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.serving.engine import ServingEngine


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    nq = 32 if quick else 64
    eng = ServingEngine(idx, replicas=2, auto_restart=True)
    timeline = []
    try:
        # phase 1: healthy
        t0 = time.perf_counter()
        qids = eng.submit(w.queries[:nq], k=C.TOPK, branching_factor=2)
        res1 = eng.collect(len(qids), timeout=120)
        qps1 = len(res1) / (time.perf_counter() - t0)
        # phase 2: kill one executor mid-service
        eng.kill_executor("exec-s1-r0")
        t0 = time.perf_counter()
        qids = eng.submit(w.queries[:nq], k=C.TOPK, branching_factor=2)
        res2 = eng.collect(len(qids), timeout=120)
        qps2 = len(res2) / (time.perf_counter() - t0)
        # phase 3: wait for monitor restart, then measure again
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline and eng.monitor.restarts == 0:
            time.sleep(0.1)
        t0 = time.perf_counter()
        qids = eng.submit(w.queries[:nq], k=C.TOPK, branching_factor=2)
        res3 = eng.collect(len(qids), timeout=120)
        qps3 = len(res3) / (time.perf_counter() - t0)
        timeline = [("healthy", qps1, len(res1)), ("failed", qps2, len(res2)),
                    ("recovered", qps3, len(res3))]
        for phase, qps, done in timeline:
            C.emit(f"fig13/{phase}", 1e6 / max(qps, 1e-9),
                   f"qps={qps:.0f};completed={done}/{nq}")
        C.emit("fig13/restarts", 0.0, f"monitor_restarts={eng.monitor.restarts}")
        assert all(done == nq for _, _, done in timeline), \
            "no queries may be lost across failure"
    finally:
        eng.shutdown()
    return timeline


if __name__ == "__main__":
    run()
