"""Paper Fig. 13: throughput timeline across an executor failure and
rejoin. Expectation: dip at failure, full recovery (no lost queries,
recall unharmed), and the supervisor restarts the executor
automatically.

The kill is scripted, not timed: a :class:`FaultSchedule` armed between
the healthy and failed phases kills ``exec-s1-r0`` at the *first batch
drained* of the failed phase — mid-batch, with items in hand. Those
items are re-enqueued (executor finally-requeue or Monitor redispatch,
whichever wins the atomic pop), the replica peer absorbs the topic, and
the Monitor respawns the executor under bounded backoff. Each phase
reports throughput, p50/p99 latency and recall@10; the engine's
recovery timeline lands in the ``BENCH_*.json`` artifact.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import common as C
from repro.serving.faults import FaultEvent, FaultSchedule

VICTIM = "exec-s1-r0"


def run(quick: bool = False, out: str | None = None):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    nq = 32 if quick else 64
    # small drain batches: the victim must drain (and so self-tick its
    # pinned kill) within a phase even when its replica peer races it
    client = C.open_client(idx, replicas=2, auto_restart=True,
                           executor_batch=4,
                           monitor_opts={"backoff_base_s": 0.05})
    eng = client.engine
    timeline = []

    def phase(label):
        t0 = time.perf_counter()
        futs = client.search_batch(w.queries[:nq], C.TOPK,
                                   branching_factor=2)
        res, timed_out = C.gather(futs, timeout=120)
        dt = time.perf_counter() - t0
        rows = {f.query_id: i for i, f in enumerate(futs)}
        row = {"phase": label, "qps": len(res) / dt,
               "completed": len(res), "timed_out": timed_out,
               "recall_at_10": C.recall_at_k(res, w.true_ids[:nq],
                                             rows=rows),
               **C.latency_summary(res)}
        timeline.append(row)
        return row

    try:
        # untimed warm pass: jit caches + latency tracker, so "healthy"
        # measures steady state rather than first-compile
        C.gather(client.search_batch(w.queries[:nq], C.TOPK,
                                     branching_factor=2), timeout=120)
        phase("healthy")
        # arm the scripted failure: when_actor pins the kill to the
        # victim's OWN next drain, so it dies holding a batch (a peer's
        # drain ticking first cannot kill it idle)
        eng.install_fault_schedule(FaultSchedule(
            [FaultEvent(step=1, action="kill", target=VICTIM,
                        when_actor=VICTIM)]))
        phase("failed")
        # pump drains until the victim has ticked its pinned kill and
        # the supervisor respawned it, then re-measure
        deadline = time.monotonic() + 20
        while (time.monotonic() < deadline
               and eng.stats()["restarts"] == 0):
            C.gather(client.search_batch(w.queries[:8], C.TOPK,
                                         branching_factor=2), timeout=60)
            time.sleep(0.05)
        phase("recovered")
        stats = eng.stats()
        for row in timeline:
            C.emit(f"fig13/{row['phase']}", 1e6 / max(row["qps"], 1e-9),
                   f"qps={row['qps']:.0f};p99_ms={row['p99_s'] * 1e3:.1f};"
                   f"recall={row['recall_at_10']:.3f};"
                   f"completed={row['completed']}/{nq}")
        C.emit("fig13/recovery", 0.0,
               f"restarts={stats['restarts']};"
               f"redispatched={stats['redispatched']};"
               f"timeline_events={len(stats['recovery_timeline'])}")
        assert all(r["completed"] == nq for r in timeline), \
            "no queries may be lost across failure"
        assert stats["restarts"] >= 1, "supervisor must respawn the victim"
        assert stats["redispatched"] >= 1, \
            "mid-batch kill must re-enqueue the victim's in-flight items"
        healthy = timeline[0]["recall_at_10"]
        assert all(abs(r["recall_at_10"] - healthy) <= 0.02
                   for r in timeline), \
            f"recall must hold across failure: {timeline}"
        C.write_bench(out, "fig13_failure", {
            "quick": quick, "n_queries": nq, "replicas": 2,
            "victim": VICTIM, "phases": timeline,
            "restarts": stats["restarts"],
            "redispatched": stats["redispatched"],
            "recovery_timeline": stats["recovery_timeline"]})
    finally:
        eng.shutdown()
    return timeline


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_fig13_failure.json")
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)
