"""Paper Fig. 13: throughput timeline across an executor failure and
rejoin. Expectation: dip at failure, full recovery (no lost queries), and
the monitor restarts the executor."""
from __future__ import annotations

import time

from benchmarks import common as C


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    nq = 32 if quick else 64
    client = C.open_client(idx, replicas=2, auto_restart=True)
    eng = client.engine
    timeline = []

    def phase_qps(label):
        t0 = time.perf_counter()
        futs = client.search_batch(w.queries[:nq], C.TOPK,
                                   branching_factor=2)
        res, _ = C.gather(futs, timeout=120)
        return label, len(res) / (time.perf_counter() - t0), len(res)

    try:
        timeline.append(phase_qps("healthy"))
        # kill one executor mid-service
        eng.kill_executor("exec-s1-r0")
        timeline.append(phase_qps("failed"))
        # wait for monitor restart, then measure again
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline and eng.monitor.restarts == 0:
            time.sleep(0.1)
        timeline.append(phase_qps("recovered"))
        for phase, qps, done in timeline:
            C.emit(f"fig13/{phase}", 1e6 / max(qps, 1e-9),
                   f"qps={qps:.0f};completed={done}/{nq}")
        C.emit("fig13/restarts", 0.0,
               f"monitor_restarts={eng.monitor.restarts}")
        assert all(done == nq for _, _, done in timeline), \
            "no queries may be lost across failure"
    finally:
        eng.shutdown()
    return timeline


if __name__ == "__main__":
    run()
