"""Compaction-under-load benchmark: serving QPS / p99 / recall during a
write storm, with the background compactor on vs off.

Runs the same deterministic write+query storm twice against a
store-published index served through :class:`repro.core.api.Brokers`:

  * **off** — records accumulate in the delta log (threshold set beyond
    the storm), so queries never share the process with a fold;
  * **on** — the background compactor thread folds the log into freshly
    published versions and hot-swaps the engine mid-storm.

Reported per mode: query QPS, p50/p99 latency, recall@10 after the
storm (the *on* run measures it on the post-swap engine over the final
corpus — inserts applied, tombstones gone), compaction cycles and
records folded. The non-``--quick`` run fails (exit 1) when compaction
degrades storm p99 by more than 2x — the "maintenance must not stall
serving" contract; CI's bench-gate additionally diffs the recall/QPS
numbers of a fresh ``--quick`` run against the committed baseline.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import numpy as np

from benchmarks import common as C
from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.api import Brokers
from repro.core.client import gather_arrays
from repro.data.synthetic import clustered_vectors
from repro.obs import MetricsRegistry
from repro.serving.engine import EngineShutdownError
from repro.store import IndexStore

P99_FACTOR = 2.0    # max allowed p99 degradation while compacting
DRAIN_S = 300.0     # max wait for the background fold to finish


def _timed_query(brokers, q):
    """One timed batch; re-resolve the engine if a background hot-swap
    retires it between lookup and submit (in-flight futures themselves
    survive a swap — ``replace_index`` drains the old engine)."""
    for _ in range(3):
        eng = brokers.get_engine("bench")
        t0 = time.perf_counter()
        try:
            ids, _ = gather_arrays(eng.submit(q, k=C.TOPK), C.TOPK, 300)
            return ids, time.perf_counter() - t0
        except EngineShutdownError:
            continue
    raise RuntimeError("query kept landing on a retiring engine")


def _recall(ids, true_ids) -> float:
    return sum(
        len(set(np.asarray(a).tolist()) & set(b.tolist()))
        for a, b in zip(ids, true_ids)) / true_ids.size


def _storm(root: str, x: np.ndarray, cfg: PyramidConfig, *,
           steps: int, q_batch: int, compact: bool,
           with_metrics: bool = False) -> dict:
    """One storm pass: journaled writes + timed query batches, the
    compactor folding in a background thread when ``compact``."""
    from repro.core.meta_index import build_pyramid_index

    rng = np.random.default_rng(17)
    n = len(x)
    store = IndexStore(root)
    store.publish(build_pyramid_index(x, cfg))

    live = {i: x[i] for i in range(n)}
    next_id, removed = n, set()
    lat = []
    # --metrics: one registry per storm pass — engine_for threads it into
    # the ServingEngine, attach_maintenance inherits it, and hot-swaps
    # preserve it (replace_index reuses the old engine's registry), so the
    # snapshot spans the whole storm including post-swap engines
    registry = MetricsRegistry() if with_metrics else None
    engine_kw = {} if registry is None else {"registry": registry}
    with Brokers() as brokers:
        brokers.engine_for("bench", store.load(), replicas=1, **engine_kw)
        comp = brokers.attach_maintenance(
            "bench", store, rebalance=False, poll_s=0.02,
            threshold_records=(24 if compact else 10 ** 9))
        if compact:
            comp.start()
        try:
            for step in range(steps):
                base = x[rng.choice(n, 2)]
                new = (base + 0.02 * rng.normal(size=base.shape)
                       ).astype(np.float32)
                comp.add_items(new)
                for v in new:
                    live[next_id] = v
                    next_id += 1
                if step % 4 == 3:
                    pool = [i for i in sorted(live) if i not in removed]
                    pick = rng.choice(len(pool), 2, replace=False)
                    victims = np.asarray([pool[int(r)] for r in pick])
                    comp.remove_items(victims)
                    removed.update(victims.tolist())
                    for v in victims.tolist():
                        del live[v]
                q = x[rng.choice(n, q_batch)]
                ids, dt = _timed_query(brokers, q)
                lat.append(dt)
        finally:
            if compact:
                # let the background fold land (slow boxes: the cycle
                # can outlast the storm) before reading the counters
                deadline = time.time() + DRAIN_S
                while comp.due() and time.time() < deadline:
                    time.sleep(0.25)
                comp.stop()
        cycles_during = comp.cycles
        comp.run_once(force=True)   # drain the tail either way

        live_ids = np.asarray(sorted(live))
        corpus = np.stack([live[i] for i in live_ids.tolist()])
        queries = corpus[np.random.default_rng(19).choice(
            len(corpus), q_batch * 4)]
        true_pos, _ = M.brute_force_topk(queries, corpus, C.TOPK, "l2")
        true_glob = live_ids[true_pos]
        ids, _ = _timed_query(brokers, queries)
        leak = set(np.asarray(ids).reshape(-1).tolist()) & removed
        assert not leak, f"deleted ids resurfaced: {sorted(leak)[:5]}"

    lat = np.asarray(lat)
    return {
        **({"metrics": registry.snapshot()} if registry is not None
           else {}),
        "compaction": "on" if compact else "off",
        "steps": steps, "q_batch": q_batch,
        "qps": round(steps * q_batch / float(lat.sum()), 1),
        "p50_ms": round(1e3 * float(np.percentile(lat, 50)) / q_batch, 3),
        "p99_ms": round(1e3 * float(np.percentile(lat, 99)) / q_batch, 3),
        "recall_at_10_final": round(_recall(ids, true_glob), 4),
        "cycles_during_storm": cycles_during,
        "records_folded": comp.folded_records,
        "delta_log_len_after": len(comp.index.delta_log()),
    }


def run(quick: bool = False, n: int | None = None,
        d: int | None = None, with_metrics: bool = False) -> list:
    n = n or (2_000 if quick else 10_000)
    d = d or (16 if quick else C.N_DIM)
    steps = 32 if quick else 96
    q_batch = 8 if quick else 16
    shards = 4 if quick else C.NUM_SHARDS
    cfg = PyramidConfig(
        metric="l2", num_shards=shards,
        meta_size=min(C.META_SIZE, max(shards, n // 16)),
        sample_size=min(n, 8_000), branching_factor=2, max_degree=16,
        max_degree_upper=8, ef_construction=60, ef_search=80,
        kmeans_iters=8, seed=0)
    x = clustered_vectors(n, d, C.N_CLUSTERS, seed=0)

    rows = []
    for compact in (False, True):
        with tempfile.TemporaryDirectory() as root:
            row = _storm(root, x, cfg, steps=steps, q_batch=q_batch,
                         compact=compact, with_metrics=with_metrics)
        rows.append(row)
        C.emit(f"compaction_{row['compaction']}",
               1e6 / row["qps"],
               f"p99={row['p99_ms']}ms "
               f"recall={row['recall_at_10_final']} "
               f"cycles={row['cycles_during_storm']}")
    assert rows[1]["cycles_during_storm"] >= 1, rows[1]
    assert rows[1]["records_folded"] >= steps, rows[1]
    assert rows[1]["delta_log_len_after"] == 0, rows[1]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--metrics", action="store_true",
                    help="embed a per-storm MetricsRegistry snapshot "
                         "in the BENCH JSON")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--d", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(quick=args.quick, n=args.n, d=args.d,
               with_metrics=args.metrics)
    payload = {"quick": args.quick, "rows": rows}
    C.write_bench(args.out, "compaction", payload)
    json.dump({"figure": "compaction", **payload}, sys.stdout, indent=2)
    print()
    off, on = rows
    if not args.quick and on["p99_ms"] > P99_FACTOR * off["p99_ms"]:
        print(f"COMPACTION GATE FAILED: p99 {on['p99_ms']}ms with "
              f"compaction active > {P99_FACTOR}x the {off['p99_ms']}ms "
              f"baseline", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
