"""Paper Fig. 9: Pyramid vs HNSW-naive vs weaker baselines.

FLANN (distributed KD-tree) is not available offline; two stand-ins play
the "algorithmically weaker third system" role: an exact linear scan
(bounds from the exact side) and a distributed LSH (PLSH [26] stand-in,
broadcast to all shards — the other system family the paper discusses).
Expectation: Pyramid >= ~2x naive throughput at comparable precision (the
paper's headline result) and far above the LSH/linear baselines.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.distributed import search_single_host
from repro.core.lsh import build_lsh, search_lsh
from repro.kernels.topk_distance import topk_similarity


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    rows = {}

    # warm jits with the FULL workload so the timed pass hits the same
    # compiled bucket sizes (steady-state serving measurement)
    search_single_host(idx, w.queries, k=C.TOPK, branching_factor=2)
    search_single_host(idx, w.queries, k=C.TOPK, naive=True)
    topk_similarity(jnp.asarray(w.queries), jnp.asarray(w.x),
                    k=C.TOPK, metric="l2")

    t0 = time.perf_counter()
    ids_p, _, mask = search_single_host(
        idx, w.queries, k=C.TOPK, branching_factor=2)
    t_p = time.perf_counter() - t0

    t0 = time.perf_counter()
    ids_n, _, _ = search_single_host(idx, w.queries, k=C.TOPK, naive=True)
    t_n = time.perf_counter() - t0

    t0 = time.perf_counter()
    _, ids_b = topk_similarity(jnp.asarray(w.queries), jnp.asarray(w.x),
                               k=C.TOPK, metric="l2")
    ids_b = np.asarray(ids_b)
    t_b = time.perf_counter() - t0

    lsh = build_lsh(w.x, metric="l2", num_shards=C.NUM_SHARDS,
                    num_tables=8, num_bits=10, width=3.0)
    search_lsh(lsh, w.queries[:4], k=C.TOPK)  # warm
    t0 = time.perf_counter()
    ids_l, _ = search_lsh(lsh, w.queries, k=C.TOPK)
    t_l = time.perf_counter() - t0

    nq = len(w.queries)
    for name, ids, t in (("pyramid", ids_p, t_p), ("hnsw_naive", ids_n, t_n),
                         ("linear_scan", ids_b, t_b),
                         ("lsh_plsh_standin", ids_l, t_l)):
        qps = nq / t
        p = C.precision(ids, w.true_ids)
        rows[name] = (qps, p)
        C.emit(f"fig9/{name}", t / nq * 1e6,
               f"qps={qps:.0f};precision={p:.3f}")
    speedup = rows["pyramid"][0] / rows["hnsw_naive"][0]
    C.emit("fig9/speedup_vs_naive", 0.0, f"speedup={speedup:.2f}x;"
           f"access_rate={mask.mean():.3f}")
    assert speedup > 1.3, f"Pyramid should beat naive: {speedup}"
    assert rows["pyramid"][1] > rows["hnsw_naive"][1] - 0.1
    return rows


if __name__ == "__main__":
    run()
