"""Paper Fig. 11: throughput scaling with the number of machines (w shards).

On this single-CPU container the w shards cannot actually run in parallel,
so we time each shard's workload separately and report the *simulated
cluster wall-clock* = max over shards (machines run concurrently; the
coordinator merge is negligible). Expectation: more shards -> higher
throughput at matched precision, with sub-linear scaling (HNSW search is
O(log n) in shard size — the paper's explanation for its 1.6-1.8x at 2x).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import hnsw as H
from repro.core.router import route_queries


def _simulated_parallel_qps(idx, queries, k, branching_factor):
    metric = idx.config.metric
    mask, _ = route_queries(
        idx.meta_arrays(), jnp.asarray(idx.part_of_center),
        jnp.asarray(queries), metric=metric,
        branching_factor=branching_factor, num_shards=idx.num_shards)
    mask = np.asarray(mask)
    shard_times = []
    all_ids = np.full((len(queries), idx.num_shards, k), -1, np.int64)
    for s in range(idx.num_shards):
        sel = np.where(mask[:, s])[0]
        if sel.size == 0:
            shard_times.append(0.0)
            continue
        arrs = idx.sub_arrays(s)
        kk = min(k, idx.subs[s].n)
        # warm this shard's jit, then time
        H.hnsw_search(arrs, jnp.asarray(queries[sel]), metric=metric,
                      k=kk, ef=idx.config.ef_search)[0].block_until_ready()
        t0 = time.perf_counter()
        ids, _ = H.hnsw_search(arrs, jnp.asarray(queries[sel]),
                               metric=metric, k=kk, ef=idx.config.ef_search)
        ids.block_until_ready()
        shard_times.append(time.perf_counter() - t0)
        all_ids[sel, s, :kk] = np.asarray(ids)
    wall = max(shard_times)
    return len(queries) / wall, all_ids.reshape(len(queries), -1)


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    rows = []
    for shards in ((4, 8) if not quick else (2, 4)):
        idx = C.build_index(w, num_shards=shards)
        qps, flat_ids = _simulated_parallel_qps(idx, w.queries, C.TOPK, 2)
        # precision from the union of returned ids
        hits = sum(
            len(set(flat_ids[i][flat_ids[i] >= 0].tolist()) &
                set(w.true_ids[i].tolist()))
            for i in range(len(w.queries)))
        p = hits / w.true_ids.size
        rows.append((shards, qps, p))
        C.emit(f"fig11/shards{shards}", 1e6 / qps,
               f"sim_parallel_qps={qps:.0f};precision={p:.3f}")
    scale = rows[-1][1] / rows[0][1]
    C.emit("fig11/scaling_factor", 0.0,
           f"speedup={scale:.2f}x_for_{rows[-1][0]//rows[0][0]}x_shards")
    if not quick:
        assert scale > 1.2, f"should scale with shards: {rows}"
    return rows


if __name__ == "__main__":
    run()
