"""Beyond-paper ablation: WHY does Pyramid work?

Isolates the two design choices of Alg. 3 by replacing each with a random
counterpart and measuring recall at fixed access rate (K=1):

  A. meta-partitioning quality: min-cut balanced partitioning of the
     meta-HNSW bottom layer vs RANDOM partition labels (same sizes);
  B. meta vertices: k-means centers vs RANDOM dataset samples.

Expectation: min-cut >> random partition (the query's neighbours
concentrate in one partition only if adjacent centers share a shard);
k-means >= random sample (statistical stability argument, Sec. III-A).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.common.config import PyramidConfig
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
import repro.core.meta_index as MI
import repro.core.partition as PT
import repro.core.kmeans as KM


def _recall_k1(idx, w):
    ids, _, mask = search_single_host(idx, w.queries, k=C.TOPK,
                                      branching_factor=1)
    return C.precision(ids, w.true_ids), mask.mean()


def run(quick: bool = False):
    # meta_size >> #natural clusters so a query's neighbours straddle
    # several meta centers — the regime where partition quality matters
    # (with ~1 center per cluster the shard of the top-1 center fully
    # determines recall and ANY balanced partition works)
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    cfg = PyramidConfig(metric="l2", num_shards=8,
                        meta_size=256 if quick else 1024,
                        sample_size=min(len(w.x), 8_000),
                        branching_factor=1, max_degree=16,
                        max_degree_upper=8, ef_construction=60,
                        ef_search=80, kmeans_iters=8)
    rows = {}

    idx = build_pyramid_index(w.x, cfg)
    rows["full"] = _recall_k1(idx, w)

    # A: random partition labels (balanced sizes, no min-cut)
    orig_pg = PT.partition_graph
    rng = np.random.default_rng(0)

    def random_partition(adj, weights, ww, **kw):
        labels = np.repeat(np.arange(ww), -(-len(weights) // ww))
        rng.shuffle(labels)
        return labels[: len(weights)].astype(np.int32)

    PT.partition_graph = random_partition
    MI.partition_graph = random_partition
    try:
        idx_rp = build_pyramid_index(w.x, cfg)
    finally:
        PT.partition_graph = orig_pg
        MI.partition_graph = orig_pg
    rows["random_partition"] = _recall_k1(idx_rp, w)

    # B: random sample instead of kmeans centers
    orig_km = KM.kmeans

    def random_centers(x, m, **kw):
        sel = np.random.default_rng(1).choice(x.shape[0], m, replace=False)
        return np.asarray(x)[sel], np.ones(m)

    MI.kmeans = random_centers
    try:
        idx_rc = build_pyramid_index(w.x, cfg)
    finally:
        MI.kmeans = orig_km
    rows["random_centers"] = _recall_k1(idx_rc, w)

    for name, (p, ar) in rows.items():
        C.emit(f"ablation/partitioner/{name}", 0.0,
               f"precision_at_K1={p:.3f};access={ar:.3f}")
    assert rows["full"][0] > rows["random_partition"][0] + 0.1, rows
    return rows


if __name__ == "__main__":
    run()
