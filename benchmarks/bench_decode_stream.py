"""Streaming retrieval-decode benchmark: tokens/s of the prefill /
insert / generate_step engine (`repro.serving.stream`) with every decode
step issuing one batched kNN lookup through the distributed engine's
futures surface against the int8 ``QuantizedShardArena``.

Grid: datastore size x concurrent sessions, each measured with the
double-buffered retrieval/decode overlap ON and OFF (``overlap=False``
is the serialized await-every-step baseline — identical tokens, no
latency hiding), plus a rerank_factor sweep at the largest config.

Row fields the CI gate consumes (benchmarks/bench_gate.py):
  * ``qps_overlap`` / ``qps_serialized`` — tokens/s (the "qps" leaves,
    gated at -30% aggregate on --quick runs);
  * ``recall_knn_hit`` — fraction of sampled tokens found among that
    step's retrieved memories. Decode is greedy and the search path is
    deterministic, so this is exactly reproducible: any drift means the
    retrieval results changed (the "recall" leaves, gated per-leaf).

Shard servers emulate the paper's REMOTE deployment: each executor
sleeps ``NET_DELAY_S`` per drained batch (RPC round-trip; pure latency,
no CPU) and lingers ``LINGER_S`` to coalesce a slot-group's fanned-out
queries into one padded op. ``REPLICAS = 2`` per shard is what makes
double-buffering pay: with a single replica the two slot groups' batches
queue behind each other's round-trip and there is nothing to pipeline
into. Hedging is off so both overlap modes issue identical search ops.

Writes ``BENCH_decode_stream.json``; at full (non --quick) scale the
summary additionally asserts overlap beats serialized at the largest
config.

PYTHONPATH=src python -m benchmarks.bench_decode_stream [--quick]
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from benchmarks import common as C
from repro.common.config import PyramidConfig
from repro.common.registry import get_arch
from repro.models.transformer import init_params
from repro.obs import MetricsRegistry
from repro.serving.batcher import Request
from repro.serving.retrieval import Datastore, build_datastore
from repro.serving.stream import StreamEngine

RERANK_FACTOR = 4
PROMPT_LEN = 12
# remote-shard emulation (see module docstring)
NET_DELAY_S = 0.040
LINGER_S = 0.002
REPLICAS = 2
EXECUTOR_BATCH = 8


def _datastore(params, cfg, n_seqs: int, seq_len: int,
               shards: int) -> Datastore:
    rng = np.random.default_rng(11)
    corpus = rng.integers(0, cfg.vocab_size,
                          size=(n_seqs, seq_len)).astype(np.int32)
    n = n_seqs * (seq_len - 1)
    pyr = PyramidConfig(
        metric="l2", num_shards=shards,
        meta_size=min(64, max(shards, n // 16)),
        sample_size=min(n, 4_000), branching_factor=2, max_degree=12,
        max_degree_upper=6, ef_construction=40, ef_search=60,
        kmeans_iters=6, seed=0)
    batches = np.array_split(corpus, max(1, n_seqs // 64))
    return build_datastore(params, cfg, batches, pyr)


def _requests(cfg, sessions: int, n_new: int, seed: int):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=PROMPT_LEN).astype(np.int32),
                    max_new_tokens=n_new) for i in range(sessions)]


def _run_engine(params, cfg, ds, reqs, *, overlap: bool, num_slots: int,
                max_seq: int, rerank_factor: int = RERANK_FACTOR,
                registry=None):
    extra = {} if registry is None else {"registry": registry}
    with StreamEngine(params, cfg, num_slots=num_slots, max_seq=max_seq,
                      datastore=ds, knn_k=8, lam=0.25, overlap=overlap,
                      quantize=True, rerank_factor=rerank_factor,
                      replicas=REPLICAS, hedge=False,
                      executor_batch=EXECUTOR_BATCH,
                      linger_s=LINGER_S, net_delay_s=NET_DELAY_S,
                      **extra) as eng:
        for r in reqs:
            eng.submit(r)
        done = eng.run_until_drained()
        st = eng.stats()
    assert len(done) == len(reqs), (len(done), len(reqs))
    tokens = {c.request_id: c.tokens for c in done}
    return tokens, st


def run(quick: bool = False, with_metrics: bool = False) -> dict:
    cfg = get_arch("qwen3-1.7b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # one registry across every measured engine: counters accumulate over
    # the grid and the snapshot lands in the BENCH JSON (--metrics)
    registry = MetricsRegistry() if with_metrics else None

    if quick:
        sizes = [(16, 17), (32, 17)]          # (n_seqs, seq_len)
        concurrency = [2, 4]
        num_slots, n_new, max_seq, shards = 4, 8, 32, 2
        rerank_factors = [1, 4]
    else:
        sizes = [(64, 33), (256, 33)]
        concurrency = [4, 16]
        num_slots, n_new, max_seq, shards = 8, 16, 48, 4
        rerank_factors = [1, 2, 4, 8]

    # warm the jit caches (decode-step per group width + prefill per
    # prompt length) on a throwaway datastore so no timed run pays
    # compile time
    warm_ds = _datastore(params, cfg, 8, PROMPT_LEN + 3, 2)
    warm = _requests(cfg, 2, 2, seed=99)
    for ov in (True, False):
        _run_engine(params, cfg, warm_ds, warm, overlap=ov,
                    num_slots=num_slots, max_seq=max_seq)

    rows = []
    largest = None
    for n_seqs, seq_len in sizes:
        ds = _datastore(params, cfg, n_seqs, seq_len, shards)
        entries = int(ds.values.shape[0])
        # one throwaway pass per datastore: the int8 arena is built
        # lazily on first search and cached on the index — without this
        # the first timed variant pays the whole quantization pass
        _run_engine(params, cfg, ds, _requests(cfg, 2, 2, seed=98),
                    overlap=True, num_slots=num_slots, max_seq=max_seq)
        for sessions in concurrency:
            reqs = _requests(cfg, sessions, n_new, seed=sessions)
            tok_o, st_o = _run_engine(params, cfg, ds, reqs,
                                      overlap=True,
                                      num_slots=num_slots,
                                      max_seq=max_seq,
                                      registry=registry)
            tok_s, st_s = _run_engine(params, cfg, ds, reqs,
                                      overlap=False,
                                      num_slots=num_slots,
                                      max_seq=max_seq,
                                      registry=registry)
            assert tok_o == tok_s, "overlap changed decode semantics"
            ret = st_o["retrieval"]
            row = {
                "datastore_entries": entries, "sessions": sessions,
                "num_slots": num_slots, "knn_k": 8,
                "rerank_factor": RERANK_FACTOR,
                "replicas": REPLICAS,
                "net_delay_ms": round(1e3 * NET_DELAY_S, 1),
                "tokens": st_o["tokens_emitted"],
                "qps_overlap": round(st_o["tokens_per_s"], 1),
                "qps_serialized": round(st_s["tokens_per_s"], 1),
                "overlap_speedup": round(
                    st_o["tokens_per_s"] / st_s["tokens_per_s"], 3),
                "recall_knn_hit": round(ret["knn_hit_rate"], 4),
                "retrieval_p50_ms": round(1e3 * ret["latency_p50_s"], 3),
                "retrieval_p99_ms": round(1e3 * ret["latency_p99_s"], 3),
                "wait_p50_ms": round(
                    1e3 * st_o["retrieval"]["wait_p50_s"], 3),
            }
            rows.append(row)
            largest = (ds, row)
            C.emit(f"decode_stream_n{entries}_c{sessions}",
                   1e6 / max(row["qps_overlap"], 1e-9),
                   f"tok/s={row['qps_overlap']} "
                   f"(serialized {row['qps_serialized']}), "
                   f"knn_hit={row['recall_knn_hit']}")

    # rerank_factor sweep at the largest (datastore, concurrency) config
    ds, _ = largest
    sweep = []
    for rf in rerank_factors:
        reqs = _requests(cfg, concurrency[-1], n_new, seed=7)
        tok, st = _run_engine(params, cfg, ds, reqs, overlap=True,
                              num_slots=num_slots, max_seq=max_seq,
                              rerank_factor=rf, registry=registry)
        ret = st["retrieval"]
        sweep.append({
            "rerank_factor": rf,
            "datastore_entries": int(ds.values.shape[0]),
            "sessions": concurrency[-1],
            "qps_overlap": round(st["tokens_per_s"], 1),
            "recall_knn_hit": round(ret["knn_hit_rate"], 4),
            "retrieval_p50_ms": round(1e3 * ret["latency_p50_s"], 3),
            "retrieval_p99_ms": round(1e3 * ret["latency_p99_s"], 3),
        })

    big = rows[-1]
    summary = {
        "largest_config": {
            "datastore_entries": big["datastore_entries"],
            "sessions": big["sessions"],
        },
        "overlap_speedup_largest": big["overlap_speedup"],
    }
    payload = {"quick": quick, "rows": rows, "rerank_sweep": sweep,
               "summary": summary}
    if registry is not None:
        payload["metrics"] = registry.snapshot()
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--metrics", action="store_true",
                    help="embed a MetricsRegistry snapshot of the "
                         "measured engines in the BENCH JSON")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    payload = run(quick=args.quick, with_metrics=args.metrics)
    C.write_bench(args.out, "decode_stream", payload)
    json.dump({"figure": "decode_stream", **payload}, sys.stdout, indent=2)
    print()
    speedup = payload["summary"]["overlap_speedup_largest"]
    if not args.quick and speedup <= 1.0:
        # the whole point of double-buffering: at the largest config the
        # hidden retrieval latency must show up as throughput
        print(f"DECODE STREAM GATE FAILED: overlap speedup {speedup} "
              f"<= 1.0 at the largest config", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
