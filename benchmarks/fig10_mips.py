"""Paper Fig. 10: MIPS with Alg. 5 (spherical kmeans + norm replication)
vs HNSW-naive, on norm-spread (Tiny-like) data.
Expectation: replication lifts precision at K=1 with small storage
overhead; Pyramid throughput beats naive."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.distributed import search_single_host


def run(quick: bool = False):
    w = C.mips_workload(n=4_000 if quick else C.N_ITEMS)
    rs = (0, 100) if not quick else (0, 50)
    rows = []
    for r in rs:
        idx = C.build_index(w, replication_r=r, branching_factor=1)
        overhead = idx.build_stats["total_stored"] / len(w.x) - 1.0
        t0 = time.perf_counter()
        ids, _, mask = search_single_host(idx, w.queries, k=C.TOPK,
                                          branching_factor=1)
        dt = time.perf_counter() - t0
        p = C.precision(ids, w.true_ids)
        rows.append((r, p, overhead))
        C.emit(f"fig10/mips/r{r}", dt / len(w.queries) * 1e6,
               f"precision={p:.3f};storage_overhead={overhead:.3f};"
               f"access={mask.mean():.3f}")

    idx = C.build_index(w, replication_r=rs[-1], branching_factor=1)
    t0 = time.perf_counter()
    ids_n, _, _ = search_single_host(idx, w.queries, k=C.TOPK, naive=True)
    t_n = time.perf_counter() - t0
    C.emit("fig10/mips_naive", t_n / len(w.queries) * 1e6,
           f"precision={C.precision(ids_n, w.true_ids):.3f}")
    assert rows[-1][1] > rows[0][1], \
        f"replication must improve MIPS precision: {rows}"
    return rows


if __name__ == "__main__":
    run()
