"""Paper Fig. 8: 90th-percentile query latency vs branching factor K,
measured through the full coordinator/executor engine (queueing included).
Expectation: p90 latency grows with K (more partials to await)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.serving.engine import ServingEngine


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    ks = (1, 2, 4) if not quick else (1, 4)
    rows = []
    nq = 64 if quick else 128
    for k in ks:
        eng = ServingEngine(idx, replicas=1)
        try:
            qids = eng.submit(w.queries[:nq], k=C.TOPK, branching_factor=k)
            res = eng.collect(len(qids), timeout=120)
            lat = np.asarray([r.latency_s for r in res])
            p90 = float(np.percentile(lat, 90)) if len(lat) else float("nan")
            rows.append((k, p90))
            C.emit(f"fig8/latency_p90/K{k}", p90 * 1e6,
                   f"p50={np.percentile(lat, 50)*1e3:.1f}ms;"
                   f"completed={len(res)}/{len(qids)}")
        finally:
            eng.shutdown()
    return rows


if __name__ == "__main__":
    run()
