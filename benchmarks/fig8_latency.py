"""Paper Fig. 8: 90th-percentile query latency vs branching factor K,
measured through the full coordinator/executor engine (queueing included).
Expectation: p90 latency grows with K (more partials to await)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    ks = (1, 2, 4) if not quick else (1, 4)
    rows = []
    nq = 64 if quick else 128
    for k in ks:
        client = C.open_client(idx, replicas=1)
        try:
            futs = client.search_batch(w.queries[:nq], C.TOPK,
                                       branching_factor=k)
            res, _ = C.gather(futs, timeout=120)
            lat = np.asarray([r.latency_s for r in res])
            p90 = float(np.percentile(lat, 90)) if len(lat) else float("nan")
            rows.append((k, p90))
            C.emit(f"fig8/latency_p90/K{k}", p90 * 1e6,
                   f"p50={np.percentile(lat, 50)*1e3:.1f}ms;"
                   f"completed={len(res)}/{len(futs)}")
        finally:
            client.engine.shutdown()
    return rows


if __name__ == "__main__":
    run()
