"""Paper Fig. 7: query throughput vs branching factor K.
Expectation: throughput drops as K grows (more shards touched per query).

Also the before/after microbench for the fused arena pipeline:

  * each K is timed end-to-end on BOTH the fused route->search->merge
    path (``search_single_host``, device-resident ShardArena) and the
    pre-arena per-shard Python loop (``search_single_host_python``);
  * the merge stage is benchmarked in isolation on the same fig7-style
    partial results: the on-device ``merge_topk`` dedup kernel vs the
    Python argsort+set loop it replaced, at the fig7 batch and at a
    serving-sized batch.

``--out`` writes everything to a ``BENCH_*.json`` artifact so CI tracks
the perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import metrics as M
from repro.core.arena import scatter_partials, shard_search
from repro.core.distributed import (python_loop_merge, search_single_host,
                                    search_single_host_python)
from repro.core.router import route_queries
from repro.kernels.merge_topk import merge_impl, merge_topk

PATHS = {
    "fused": search_single_host,
    "python": search_single_host_python,
}


def _best_of(fn, reps: int = 3) -> float:
    """Min wall-clock over ``reps`` runs (noise-robust CI timing)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _merge_microbench(idx, workload, repeat_queries: int = 8):
    """Time ONLY the coordinator merge, before vs after, on real fig7
    partials (route + per-shard search once, then merge both ways)."""
    q = M.preprocess_queries(workload.queries, workload.metric)
    qj = jnp.asarray(q)
    mask, _ = route_queries(
        idx.meta_arrays(), jnp.asarray(idx.part_of_center), qj,
        metric=idx.config.metric, branching_factor=2,
        num_shards=idx.num_shards, ef=64)
    b = q.shape[0]
    cap = int(np.asarray(mask).sum(axis=0).max())
    fn = jax.jit(lambda a, m, queries: scatter_partials(
        *shard_search(a, m, queries, metric=idx.config.metric, k=C.TOPK,
                      ef=idx.config.ef_search, capacity=cap,
                      shard_axis="map"), b))
    flat_s, flat_i = fn(idx.arena(), mask, qj)
    out = {}
    for tile in (1, repeat_queries):
        fs = jnp.tile(flat_s, (tile, 1))
        fi = jnp.tile(flat_i, (tile, 1))
        rows = fs.shape[0]
        dev = jax.jit(lambda s, i: merge_topk(s, i, k=C.TOPK))
        jax.block_until_ready(dev(fs, fi))          # warm
        t_device = _best_of(lambda: jax.block_until_ready(dev(fs, fi)))
        fs_n, fi_n = np.asarray(fs), np.asarray(fi)
        t_python = _best_of(lambda: python_loop_merge(fs_n, fi_n, C.TOPK))
        out[f"batch_{rows}"] = {
            "device_us_per_query": t_device / rows * 1e6,
            "python_us_per_query": t_python / rows * 1e6,
            "device_speedup": t_python / t_device,
        }
        C.emit(f"fig7/merge/device/B{rows}", t_device / rows * 1e6,
               f"speedup_vs_python={t_python / t_device:.2f}x")
        C.emit(f"fig7/merge/python/B{rows}", t_python / rows * 1e6, "-")
    # record which merge implementation merge_topk actually dispatched
    out["merge_impl"] = merge_impl()
    out["backend"] = jax.default_backend()
    return out


def run(quick: bool = False, out: str | None = None):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    ks = (1, 2, 4, 8) if not quick else (1, 4)
    rows = []
    for k in ks:
        row = {"K": k}
        for name, fn in PATHS.items():
            # warm the jit caches for this (path, K) before timing
            ids, _, _ = fn(idx, w.queries, k=C.TOPK, branching_factor=k)
            dt = _best_of(
                lambda: fn(idx, w.queries, k=C.TOPK, branching_factor=k))
            qps = len(w.queries) / dt
            prec = C.precision(ids, w.true_ids)
            row[name] = {"qps": qps, "precision": prec,
                         "us_per_query": dt / len(w.queries) * 1e6}
            C.emit(f"fig7/throughput/{name}/K{k}",
                   dt / len(w.queries) * 1e6,
                   f"qps={qps:.0f};precision={prec:.3f}")
        row["fused_speedup"] = row["fused"]["qps"] / row["python"]["qps"]
        rows.append(row)
    merge_rows = _merge_microbench(idx, w)
    if not quick:  # at tiny quick-mode scale fixed overheads dominate
        assert rows[0]["fused"]["qps"] > rows[-1]["fused"]["qps"], \
            f"throughput should drop with K: {rows}"
    if out:
        with open(C.ensure_parent(out), "w") as f:
            json.dump({"figure": "fig7_throughput",
                       "quick": quick,
                       "n_items": 4_000 if quick else C.N_ITEMS,
                       "n_queries": len(w.queries),
                       "rows": rows,
                       "merge_microbench": merge_rows}, f, indent=2)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small dataset (CI-speed)")
    ap.add_argument("--out", default="BENCH_fig7_throughput.json",
                    help="write rows to this BENCH_*.json artifact")
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)
