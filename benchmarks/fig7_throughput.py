"""Paper Fig. 7: query throughput vs branching factor K.
Expectation: throughput drops as K grows (more shards touched per query)."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.distributed import search_single_host


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    ks = (1, 2, 4, 8) if not quick else (1, 4)
    rows = []
    # warm the jit caches
    search_single_host(idx, w.queries[:8], k=C.TOPK, branching_factor=1)
    for k in ks:
        t0 = time.perf_counter()
        ids, _, mask = search_single_host(
            idx, w.queries, k=C.TOPK, branching_factor=k)
        dt = time.perf_counter() - t0
        qps = len(w.queries) / dt
        rows.append((k, qps))
        C.emit(f"fig7/throughput/K{k}", dt / len(w.queries) * 1e6,
               f"qps={qps:.0f};precision={C.precision(ids, w.true_ids):.3f}")
    if not quick:  # at tiny quick-mode scale fixed overheads dominate
        assert rows[0][1] > rows[-1][1], \
            f"throughput should drop with K: {rows}"
    return rows


if __name__ == "__main__":
    run()
