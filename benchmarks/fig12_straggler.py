"""Paper Fig. 12: throughput under a straggler at varying CPU share.
Expectation: with 2x replication, throughput holds until the straggler is
extremely slow (paper: stable above ~30% CPU share)."""
from __future__ import annotations

import time

from benchmarks import common as C


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    shares = (1.0, 0.5, 0.1) if not quick else (1.0, 0.1)
    nq = 64 if quick else 128
    rows = []
    for share in shares:
        client = C.open_client(idx, replicas=2)
        try:
            client.engine.set_cpu_share("exec-s0-r0", share)
            t0 = time.perf_counter()
            futs = client.search_batch(w.queries[:nq], C.TOPK,
                                       branching_factor=2)
            res, _ = C.gather(futs, timeout=180)
            dt = time.perf_counter() - t0
            qps = len(res) / dt
            rows.append((share, qps, len(res)))
            C.emit(f"fig12/straggler_share{share}", dt / max(len(res), 1)
                   * 1e6, f"qps={qps:.0f};completed={len(res)}/{len(futs)}")
        finally:
            client.engine.shutdown()
    assert rows[0][2] == nq
    return rows


if __name__ == "__main__":
    run()
