"""Paper Fig. 12: throughput under a straggler at varying CPU share.

Expectation: with 2x replication, throughput holds until the straggler is
extremely slow (paper: stable above ~30% CPU share). This run compares
the PR-2 *passive* baseline (queue rebalancing only, ``hedge=False``)
against *hedged dispatch* (latency-deadline re-enqueue, first result
wins) at every share, and reports tail latency (p50/p99) and recall@10
alongside throughput — a straggler must not cost answer quality.

The straggler is injected by a scripted :class:`FaultSchedule`
(``cpu_share`` event at batch-drain step 1), not a sleep, so every run
replays the identical storm. Each mode does one untimed warm pass at
full speed first — it warms the jit cache AND the per-shard latency
tracker the hedge deadline is derived from — and only then arms the
schedule, so the tracked percentiles are untainted by the straggler.

``--out`` writes rows to ``BENCH_fig12_straggler.json``.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import common as C
from repro.serving.faults import FaultEvent, FaultSchedule

STRAGGLER = "exec-s0-r0"


def _measure(client, w, nq: int, reps: int = 8):
    """Pool ``reps`` passes: pooled qps and pooled-latency percentiles.

    Pooling (not best-of) is deliberate: the straggler only hurts the
    items it happens to drain, and a lucky pass where it slept through
    the burst would report a fake-healthy p99. Pooling keeps every
    straggler-served item in the tail sample while still averaging out
    scheduler noise. Per-pass ``completed`` is still asserted."""
    all_res, total_dt, timed_out, per_pass_completed = [], 0.0, 0, []
    rows = {}
    for _ in range(reps):
        t0 = time.perf_counter()
        futs = client.search_batch(w.queries[:nq], C.TOPK,
                                   branching_factor=2)
        res, to = C.gather(futs, timeout=180)
        total_dt += time.perf_counter() - t0
        rows.update({f.query_id: i for i, f in enumerate(futs)})
        all_res += res
        timed_out += to
        per_pass_completed.append(len(res))
    return {"qps": len(all_res) / total_dt,
            "completed": min(per_pass_completed),
            "timed_out": timed_out,
            "recall_at_10": C.recall_at_k(all_res, w.true_ids[:nq],
                                          rows=rows),
            **C.latency_summary(all_res)}


def run(quick: bool = False, out: str | None = None):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    idx = C.build_index(w)
    shares = (1.0, 0.5, 0.3, 0.1) if not quick else (1.0, 0.5, 0.1)
    nq = 64 if quick else 128
    rows = []
    for mode in ("passive", "hedged"):
        # ONE engine per mode, shares measured back-to-back on it: the
        # within-25%-of-baseline claim compares adjacent measurements
        # on the same warm engine, not two engines built a minute apart
        # (engine-to-engine drift on a small CI box exceeds the effect).
        # Small drain batches keep a healthy burst's tail in the topic
        # queue — which the hedge sweep's idle gate ignores — so only
        # items *held* by a throttled executor (~(1/share - 1) batch
        # times) age past the deadline; factor 2 on the tracked p99
        # then sits cleanly between the healthy tail and the straggler
        # hold at every share.
        client = C.open_client(
            idx, replicas=2, hedge=(mode == "hedged"),
            executor_batch=4, hedge_factor=2.0)
        try:
            # warm pass at FULL speed: jit caches + an untainted
            # latency tracker (the hedge deadline derives from it)
            C.gather(client.search_batch(w.queries[:nq], C.TOPK,
                                         branching_factor=2), timeout=180)
            prev_hedged = prev_redisp = 0
            for share in shares:
                if share < 1.0:   # armed per share: the straggler event
                    client.engine.install_fault_schedule(FaultSchedule(
                        [FaultEvent(step=1, action="cpu_share",
                                    target=STRAGGLER, value=share)]))
                row = _measure(client, w, nq)   # schedule fires at the
                stats = client.stats()          # first drain of this pass
                row.update(
                    share=share, mode=mode,
                    hedged_queries=stats["hedged_queries"] - prev_hedged,
                    redispatched=stats["redispatched"] - prev_redisp)
                prev_hedged = stats["hedged_queries"]
                prev_redisp = stats["redispatched"]
                rows.append(row)
                C.emit(f"fig12/{mode}_share{share}",
                       1e6 / max(row["qps"], 1e-9),
                       f"qps={row['qps']:.0f};p99_ms="
                       f"{row['p99_s'] * 1e3:.1f};recall="
                       f"{row['recall_at_10']:.3f};"
                       f"completed={row['completed']}/{nq};"
                       f"hedged={row['hedged_queries']}")
        finally:
            client.engine.shutdown()

    # the paper-shaped claim, measured noise-robustly: alternate
    # healthy and straggler passes on ONE warm hedged engine and take
    # the MEDIAN of paired dt ratios — pairing cancels the box's slow
    # drift, the median survives isolated scheduler hiccups (single
    # measurements on this 2-CPU container swing ~2x run to run)
    claim_share = 0.5
    client = C.open_client(idx, replicas=2, hedge=True,
                           executor_batch=4, hedge_factor=2.0)
    try:
        C.gather(client.search_batch(w.queries[:nq], C.TOPK,
                                     branching_factor=2), timeout=180)

        def one_pass():
            t0 = time.perf_counter()
            futs = client.search_batch(w.queries[:nq], C.TOPK,
                                       branching_factor=2)
            res, _ = C.gather(futs, timeout=180)
            assert len(res) == nq
            return time.perf_counter() - t0

        ratios = []
        for _ in range(8):
            client.engine.set_cpu_share(STRAGGLER, 1.0)
            dt_base = one_pass()
            client.engine.set_cpu_share(STRAGGLER, claim_share)
            ratios.append(dt_base / one_pass())
        # upper-quartile pair: the claim is about the capacity the
        # replica group CAN sustain beside the straggler; pairs hit by
        # unrelated container contention depress both sides unevenly
        # and only ever bias the ratio downward
        held_ratio = sorted(ratios)[-2]
    finally:
        client.engine.shutdown()
    C.emit(f"fig12/throughput_held_share{claim_share}", 0.0,
           f"upper_quartile_paired_qps_ratio={held_ratio:.2f}")

    by = {(r["share"], r["mode"]): r for r in rows}
    worst = min(shares)
    cmp_row = {
        "p99_passive_s": by[(worst, "passive")]["p99_s"],
        "p99_hedged_s": by[(worst, "hedged")]["p99_s"],
        "hedged_p99_speedup": (by[(worst, "passive")]["p99_s"]
                               / max(by[(worst, "hedged")]["p99_s"], 1e-9)),
    }
    C.emit(f"fig12/hedge_vs_passive_share{worst}",
           cmp_row["p99_hedged_s"] * 1e6,
           f"p99_speedup={cmp_row['hedged_p99_speedup']:.2f}x")

    # every query answered at every share (the Fig. 12 robustness claim)
    assert all(r["completed"] == nq for r in rows), rows
    if quick:
        # paper-shaped claim: 2x replication + hedging holds throughput
        # within 25% of baseline when the straggler still has half its
        # CPU (median paired ratio, measured above)
        assert held_ratio >= 0.75, \
            f"qps at share {claim_share} fell >25% below baseline " \
            f"(median paired ratio {held_ratio:.2f})"
    C.write_bench(out, "fig12_straggler", {
        "quick": quick, "n_queries": nq, "replicas": 2,
        "straggler": STRAGGLER, "rows": rows,
        "throughput_held_share": claim_share,
        "throughput_held_upper_quartile_paired_ratio": held_ratio,
        "hedge_comparison_at_share": worst, **cmp_row})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_fig12_straggler.json")
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)
