"""CI perf/recall regression gate over ``BENCH_*.json`` artifacts.

Diffs every freshly generated artifact in ``--fresh`` against the
committed baseline copy (``--baseline``, default
``benchmarks/baselines``) and fails (exit 1) when:

  * any numeric leaf whose key contains ``recall`` dropped by more than
    ``--recall-tol`` (absolute, default 0.02 = 2%);
  * an artifact's AGGREGATE quick-mode QPS (sum over its ``qps`` leaves,
    path-aligned) dropped below ``(1 - --qps-tol)`` of the baseline
    (default 0.30 = 30%). Aggregating per artifact instead of per leaf
    is deliberate: single quick-mode timings swing ~2x on shared 2-CPU
    runners (see CHANGES.md PR 3), so one noisy straggler-share row must
    not fail an honest run — a real regression moves the whole artifact.
    Applied only when BOTH artifacts are quick-mode runs
    (``"quick": true``); full-scale and quick numbers are not
    comparable;
  * a baseline artifact has no fresh counterpart (a benchmark silently
    dropped out of CI), or a gated leaf vanished from the fresh payload.

Leaves are aligned by JSON path (dict keys + list indices), so per-row
tables (fig12 shares x modes, quant metrics) compare row-for-row.
Improvements never fail the gate.

Refreshing baselines (after an intentional perf/recall change)::

    PYTHONPATH=src python -m benchmarks.bench_gate \\
        --fresh fresh-bench --update-baselines

which copies the fresh artifacts over ``benchmarks/baselines/`` —
commit the result. The CI workflow documents the same flow.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, Iterator, Tuple

RECALL_TOL = 0.02
QPS_TOL = 0.30


def _numeric_leaves(obj, path: str = "") -> Iterator[Tuple[str, float]]:
    if isinstance(obj, dict):
        for key in sorted(obj):
            yield from _numeric_leaves(obj[key], f"{path}/{key}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _numeric_leaves(v, f"{path}[{i}]")
    elif isinstance(obj, bool):
        return
    elif isinstance(obj, (int, float)):
        yield path, float(obj)


def _last_key(path: str) -> str:
    return path.rsplit("/", 1)[-1].split("[")[0].lower()


def _is_quick(payload) -> bool:
    return isinstance(payload, dict) and payload.get("quick") is True


def gate_file(name: str, baseline, fresh, *, recall_tol: float,
              qps_tol: float) -> Tuple[list, list]:
    """Returns (violations, notes) for one artifact pair."""
    violations, notes = [], []
    base_leaves: Dict[str, float] = dict(_numeric_leaves(baseline))
    fresh_leaves: Dict[str, float] = dict(_numeric_leaves(fresh))
    qps_comparable = _is_quick(baseline) and _is_quick(fresh)
    qps_base_sum = qps_fresh_sum = 0.0
    qps_count = 0
    for path, base in sorted(base_leaves.items()):
        key = _last_key(path)
        is_recall = "recall" in key
        is_qps = "qps" in key
        if not (is_recall or is_qps):
            continue
        if path not in fresh_leaves:
            violations.append(
                f"{name}{path}: gated metric missing from fresh run")
            continue
        got = fresh_leaves[path]
        if is_recall and got < base - recall_tol:
            violations.append(
                f"{name}{path}: recall {got:.4f} < baseline "
                f"{base:.4f} - {recall_tol} (regression "
                f"{base - got:.4f})")
        elif is_qps:
            qps_base_sum += base
            qps_fresh_sum += got
            qps_count += 1
    if qps_count and not qps_comparable:
        notes.append(
            f"{name}: {qps_count} qps leaves not gated (artifacts are "
            "not both quick-mode runs)")
    elif (qps_count and qps_base_sum > 0
            and qps_fresh_sum < (1.0 - qps_tol) * qps_base_sum):
        violations.append(
            f"{name}: aggregate qps over {qps_count} leaves "
            f"{qps_fresh_sum:.1f} < {1.0 - qps_tol:.2f} x baseline "
            f"{qps_base_sum:.1f} "
            f"(-{100 * (1 - qps_fresh_sum / qps_base_sum):.0f}%)")
    return violations, notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="directory with freshly generated BENCH_*.json")
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory with the committed baselines")
    ap.add_argument("--recall-tol", type=float, default=RECALL_TOL)
    ap.add_argument("--qps-tol", type=float, default=QPS_TOL)
    ap.add_argument("--update-baselines", action="store_true",
                    help="copy the fresh artifacts over the baselines "
                         "(then commit them) instead of gating")
    args = ap.parse_args()

    names = sorted(f for f in os.listdir(args.baseline)
                   if f.startswith("BENCH_") and f.endswith(".json")) \
        if os.path.isdir(args.baseline) else []
    if args.update_baselines:
        os.makedirs(args.baseline, exist_ok=True)
        fresh_names = sorted(
            f for f in os.listdir(args.fresh)
            if f.startswith("BENCH_") and f.endswith(".json"))
        for f in fresh_names:
            shutil.copyfile(os.path.join(args.fresh, f),
                            os.path.join(args.baseline, f))
            print(f"bench-gate: baseline refreshed: {f}")
        if not fresh_names:
            print("bench-gate: no fresh BENCH_*.json to adopt",
                  file=sys.stderr)
            sys.exit(1)
        return
    if not names:
        print(f"bench-gate: no baselines under {args.baseline}; "
              "run with --update-baselines to create them",
              file=sys.stderr)
        sys.exit(1)

    all_violations, checked = [], 0
    for name in names:
        fresh_path = os.path.join(args.fresh, name)
        if not os.path.exists(fresh_path):
            all_violations.append(
                f"{name}: baseline exists but no fresh artifact was "
                "generated")
            continue
        with open(os.path.join(args.baseline, name)) as f:
            baseline = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        violations, notes = gate_file(
            name, baseline, fresh, recall_tol=args.recall_tol,
            qps_tol=args.qps_tol)
        checked += 1
        for n in notes:
            print(f"bench-gate: note: {n}")
        all_violations.extend(violations)

    if all_violations:
        print(f"bench-gate: FAILED ({len(all_violations)} violations "
              f"over {checked} artifacts):", file=sys.stderr)
        for v in all_violations:
            print(f"  {v}", file=sys.stderr)
        print("bench-gate: if the change is intentional, refresh with "
              "--update-baselines and commit", file=sys.stderr)
        sys.exit(1)
    print(f"bench-gate: OK ({checked} artifacts within tolerance: "
          f"recall -{args.recall_tol}, quick qps -{args.qps_tol:.0%})")


if __name__ == "__main__":
    main()
