"""CI perf/recall regression gate over ``BENCH_*.json`` artifacts.

Diffs every freshly generated artifact in ``--fresh`` against the
committed baseline copy (``--baseline``, default
``benchmarks/baselines``) and fails (exit 1) when:

  * any numeric leaf whose key contains ``recall`` dropped by more than
    ``--recall-tol`` (absolute, default 0.02 = 2%);
  * an artifact's AGGREGATE quick-mode QPS (sum over its ``qps`` leaves,
    path-aligned) dropped below ``(1 - --qps-tol)`` of the baseline
    (default 0.30 = 30%). Aggregating per artifact instead of per leaf
    is deliberate: single quick-mode timings swing ~2x on shared 2-CPU
    runners (see CHANGES.md PR 3), so one noisy straggler-share row must
    not fail an honest run — a real regression moves the whole artifact.
    Applied only when BOTH artifacts are quick-mode runs
    (``"quick": true``); full-scale and quick numbers are not
    comparable;
  * a baseline artifact has no fresh counterpart (a benchmark silently
    dropped out of CI), or a gated leaf vanished from the fresh payload;
  * a suite registered as gated in ``benchmarks/suites.py`` has no
    committed baseline at all (a new benchmark cannot land ungated).

Leaves are aligned by JSON path (dict keys + list indices), so per-row
tables (fig12 shares x modes, quant metrics) compare row-for-row.
Improvements never fail the gate.

Refreshing baselines (after an intentional perf/recall change)::

    PYTHONPATH=src python -m benchmarks.bench_gate \\
        --fresh fresh-bench --update-baselines

which copies the fresh artifacts over ``benchmarks/baselines/`` —
commit the result. The CI workflow documents the same flow.

Observability overhead gate (``--obs-overhead``): one engine serves
interleaved query passes with its ``Tracer`` toggled off/on. Three
checks:

  * **implied tracing overhead < ``--overhead-tol`` (default 3%)** —
    computed as (spans recorded per query) x (microbenched cost per
    span op) / (per-query latency with tracing off). Every factor is a
    low-variance measurement, so this assertion is CI-stable; a direct
    wall-clock A/B is not — on shared 2-CPU runners the run-to-run QPS
    noise of an *unchanged* engine is +-5% (measured), far above the
    ~1% signal.
  * **wall-clock A/B sanity ceiling ``--overhead-ceiling`` (default
    25%)** — the paired off/on QPS comparison is printed for the log
    and only fails the gate when tracing-on falls off a cliff.
  * **disabled-path microbench < ``--disabled-ns``/op** — a disabled
    registry's ``counter.inc`` and a ``NULL_TRACER`` span must stay
    near-free, since the hot path keeps its instrumentation callsites
    even with observability off.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, Iterator, Tuple

RECALL_TOL = 0.02
QPS_TOL = 0.30
OVERHEAD_TOL = 0.03       # tracing-on may cost at most 3% QPS (implied)
OVERHEAD_CEILING = 0.25   # wall-clock A/B hard sanity ceiling
DISABLED_NS = 2000.0      # ns/op ceiling for disabled counters / null spans


def _numeric_leaves(obj, path: str = "") -> Iterator[Tuple[str, float]]:
    if isinstance(obj, dict):
        for key in sorted(obj):
            yield from _numeric_leaves(obj[key], f"{path}/{key}")
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _numeric_leaves(v, f"{path}[{i}]")
    elif isinstance(obj, bool):
        return
    elif isinstance(obj, (int, float)):
        yield path, float(obj)


def _last_key(path: str) -> str:
    return path.rsplit("/", 1)[-1].split("[")[0].lower()


def _is_quick(payload) -> bool:
    return isinstance(payload, dict) and payload.get("quick") is True


def gate_file(name: str, baseline, fresh, *, recall_tol: float,
              qps_tol: float) -> Tuple[list, list]:
    """Returns (violations, notes) for one artifact pair."""
    violations, notes = [], []
    base_leaves: Dict[str, float] = dict(_numeric_leaves(baseline))
    fresh_leaves: Dict[str, float] = dict(_numeric_leaves(fresh))
    qps_comparable = _is_quick(baseline) and _is_quick(fresh)
    qps_base_sum = qps_fresh_sum = 0.0
    qps_count = 0
    for path, base in sorted(base_leaves.items()):
        key = _last_key(path)
        is_recall = "recall" in key
        is_qps = "qps" in key
        if not (is_recall or is_qps):
            continue
        if path not in fresh_leaves:
            violations.append(
                f"{name}{path}: gated metric missing from fresh run")
            continue
        got = fresh_leaves[path]
        if is_recall and got < base - recall_tol:
            violations.append(
                f"{name}{path}: recall {got:.4f} < baseline "
                f"{base:.4f} - {recall_tol} (regression "
                f"{base - got:.4f})")
        elif is_qps:
            qps_base_sum += base
            qps_fresh_sum += got
            qps_count += 1
    if qps_count and not qps_comparable:
        notes.append(
            f"{name}: {qps_count} qps leaves not gated (artifacts are "
            "not both quick-mode runs)")
    elif (qps_count and qps_base_sum > 0
            and qps_fresh_sum < (1.0 - qps_tol) * qps_base_sum):
        violations.append(
            f"{name}: aggregate qps over {qps_count} leaves "
            f"{qps_fresh_sum:.1f} < {1.0 - qps_tol:.2f} x baseline "
            f"{qps_base_sum:.1f} "
            f"(-{100 * (1 - qps_fresh_sum / qps_base_sum):.0f}%)")
    return violations, notes


def _span_op_cost(tracer, iters: int = 20_000) -> float:
    """Seconds per live ``span()`` context-manager op (the dominant
    per-query tracing cost in the engine hot path)."""
    import time
    t0 = time.perf_counter()
    for _ in range(iters):
        with tracer.span("obs_overhead_probe", i=0):
            pass
    return (time.perf_counter() - t0) / iters


def run_obs_overhead(*, quick: bool, tol: float, ceiling: float,
                     disabled_ns: float) -> None:
    """Obs-on vs obs-off QPS comparison + disabled-path microbench.
    Exits 1 on violation. Imports the repro stack lazily so the plain
    artifact-diff path keeps working without jax installed."""
    import time

    from repro.common.config import PyramidConfig
    from repro.core.client import gather_arrays
    from repro.core.meta_index import build_pyramid_index
    from repro.data.synthetic import clustered_vectors, query_set
    from repro.obs import NULL_TRACER, MetricsRegistry, Tracer
    from repro.serving.engine import ServingEngine

    n, d, reps, batches = ((1500, 12, 5, 6) if quick
                           else (6000, 24, 9, 10))
    x = clustered_vectors(n, d, 12, seed=0)
    cfg = PyramidConfig(
        metric="l2", num_shards=4, meta_size=48,
        sample_size=min(n, 800), branching_factor=2, max_degree=12,
        max_degree_upper=6, ef_construction=40, ef_search=50,
        kmeans_iters=6, seed=0)
    index = build_pyramid_index(x, cfg)
    q = query_set(x, 24, seed=3)
    k = 10

    def timed(eng) -> float:
        # several sequential batches per timing so the pass is long
        # enough (~100ms) that thread-scheduling jitter cannot swamp a
        # few-percent per-query difference
        t0 = time.perf_counter()
        for _ in range(batches):
            gather_arrays(eng.submit(q, k=k), k, 60.0)
        return time.perf_counter() - t0

    # Paired design: ONE engine, toggling ``tracer.enabled`` between
    # quiescent passes, so the off/on passes share every confounder
    # (thread placement, queue dynamics, jit caches). Hedging off: the
    # hedge sweep's timer-driven re-dispatches must not perturb a pass.
    # Metrics stay enabled in both modes — their cost is bounded
    # separately by the disabled-path microbench below.
    tracer = Tracer()
    eng = ServingEngine(index, hedge=False, registry=MetricsRegistry(),
                        tracer=tracer)
    times = {"off": [], "on": []}
    nq = batches * len(q)
    try:
        for mode in ("off", "on"):      # warm executors + jit caches
            tracer.enabled = mode == "on"
            timed(eng)
            timed(eng)
        n0 = len(tracer.snapshot())
        for _ in range(reps):           # interleaved off/on pairs
            for mode in ("off", "on"):
                tracer.enabled = mode == "on"
                times[mode].append(timed(eng))
        spans_per_query = (len(tracer.snapshot()) - n0) / (reps * nq)
    finally:
        eng.shutdown()
    best_off, best_on = min(times["off"]), min(times["on"])
    measured = best_on / best_off - 1.0

    # cost of one live span op: best of 3 tight microbench rounds (the
    # low-variance estimator — unlike pass wall-clock, a 20k-iteration
    # spin is immune to scheduler preemption at the percent level)
    tracer.enabled = True
    span_cost = min(_span_op_cost(tracer) for _ in range(3))
    per_query_off = best_off / nq
    implied = spans_per_query * span_cost / per_query_off
    print(f"bench-gate: obs-overhead: off={best_off * 1e3:.2f}ms "
          f"on={best_on * 1e3:.2f}ms (best of {reps}) "
          f"measured={100 * measured:+.2f}% "
          f"(sanity ceiling {100 * ceiling:.0f}%)")
    print(f"bench-gate: obs-overhead: {spans_per_query:.2f} spans/query "
          f"x {span_cost * 1e9:.0f}ns/span / "
          f"{per_query_off * 1e6:.0f}us/query -> implied "
          f"{100 * implied:.2f}% (tol {100 * tol:.0f}%)")

    # disabled-path microbench: the hot path keeps its counters/spans
    # even with obs off, so the off cost must stay near zero per op
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("bench_gate_disabled_total", "overhead probe")
    iters = 200_000
    t0 = time.perf_counter()
    for _ in range(iters):
        c.inc()
    inc_ns = (time.perf_counter() - t0) / iters * 1e9
    t0 = time.perf_counter()
    for _ in range(iters // 10):
        with NULL_TRACER.span("probe"):
            pass
    span_ns = (time.perf_counter() - t0) / (iters // 10) * 1e9
    print(f"bench-gate: obs-overhead: disabled counter.inc "
          f"{inc_ns:.0f}ns/op, null span {span_ns:.0f}ns/op "
          f"(ceiling {disabled_ns:.0f}ns)")

    violations = []
    if implied > tol:
        violations.append(
            f"implied tracing overhead {100 * implied:.2f}% > "
            f"{100 * tol:.0f}% tolerance")
    if measured > ceiling:
        violations.append(
            f"measured tracing-on QPS overhead {100 * measured:.2f}% > "
            f"{100 * ceiling:.0f}% sanity ceiling")
    if inc_ns > disabled_ns or span_ns > disabled_ns:
        violations.append(
            f"disabled-path cost ({inc_ns:.0f}ns inc / {span_ns:.0f}ns "
            f"span) above the {disabled_ns:.0f}ns/op ceiling")
    if violations:
        for v in violations:
            print(f"bench-gate: obs-overhead FAILED: {v}",
                  file=sys.stderr)
        sys.exit(1)
    print("bench-gate: obs-overhead OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=None,
                    help="directory with freshly generated BENCH_*.json")
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory with the committed baselines")
    ap.add_argument("--recall-tol", type=float, default=RECALL_TOL)
    ap.add_argument("--qps-tol", type=float, default=QPS_TOL)
    ap.add_argument("--update-baselines", action="store_true",
                    help="copy the fresh artifacts over the baselines "
                         "(then commit them) instead of gating")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="run the observability-overhead gate instead "
                         "of the artifact diff (no --fresh needed)")
    ap.add_argument("--quick", action="store_true",
                    help="with --obs-overhead: smaller index / fewer "
                         "repetitions")
    ap.add_argument("--overhead-tol", type=float, default=OVERHEAD_TOL)
    ap.add_argument("--overhead-ceiling", type=float,
                    default=OVERHEAD_CEILING)
    ap.add_argument("--disabled-ns", type=float, default=DISABLED_NS)
    args = ap.parse_args()

    if args.obs_overhead:
        run_obs_overhead(quick=args.quick, tol=args.overhead_tol,
                         ceiling=args.overhead_ceiling,
                         disabled_ns=args.disabled_ns)
        return
    if not args.fresh:
        ap.error("--fresh is required (unless --obs-overhead)")

    names = sorted(f for f in os.listdir(args.baseline)
                   if f.startswith("BENCH_") and f.endswith(".json")) \
        if os.path.isdir(args.baseline) else []
    if args.update_baselines:
        os.makedirs(args.baseline, exist_ok=True)
        fresh_names = sorted(
            f for f in os.listdir(args.fresh)
            if f.startswith("BENCH_") and f.endswith(".json"))
        for f in fresh_names:
            shutil.copyfile(os.path.join(args.fresh, f),
                            os.path.join(args.baseline, f))
            print(f"bench-gate: baseline refreshed: {f}")
        if not fresh_names:
            print("bench-gate: no fresh BENCH_*.json to adopt",
                  file=sys.stderr)
            sys.exit(1)
        return
    if not names:
        print(f"bench-gate: no baselines under {args.baseline}; "
              "run with --update-baselines to create them",
              file=sys.stderr)
        sys.exit(1)

    all_violations, checked = [], 0
    # registry completeness: every gated suite in benchmarks/suites.py
    # must have a committed quick baseline — a suite added to the
    # registry (and thus to CI) cannot silently run ungated
    from benchmarks import suites as suite_registry
    for suite in suite_registry.gated_suites():
        if suite.artifact not in names:
            all_violations.append(
                f"{suite.artifact}: suite {suite.name!r} is registered "
                f"as gated in benchmarks/suites.py but has no committed "
                f"baseline under {args.baseline} (run it with --quick "
                f"and adopt via --update-baselines)")
    for name in names:
        fresh_path = os.path.join(args.fresh, name)
        if not os.path.exists(fresh_path):
            all_violations.append(
                f"{name}: baseline exists but no fresh artifact was "
                "generated")
            continue
        with open(os.path.join(args.baseline, name)) as f:
            baseline = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        violations, notes = gate_file(
            name, baseline, fresh, recall_tol=args.recall_tol,
            qps_tol=args.qps_tol)
        checked += 1
        for n in notes:
            print(f"bench-gate: note: {n}")
        all_violations.extend(violations)

    if all_violations:
        print(f"bench-gate: FAILED ({len(all_violations)} violations "
              f"over {checked} artifacts):", file=sys.stderr)
        for v in all_violations:
            print(f"  {v}", file=sys.stderr)
        print("bench-gate: if the change is intentional, refresh with "
              "--update-baselines and commit", file=sys.stderr)
        sys.exit(1)
    print(f"bench-gate: OK ({checked} artifacts within tolerance: "
          f"recall -{args.recall_tol}, quick qps -{args.qps_tol:.0%})")


if __name__ == "__main__":
    main()
