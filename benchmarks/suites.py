"""Self-describing benchmark-suite registry.

One table (``SUITES``) describes every benchmark CI runs: module,
artifact filename, extra CLI args, and whether the artifact is gated by
``benchmarks/bench_gate.py`` against a committed quick baseline. Both CI
bench jobs are a single loop over this registry::

    PYTHONPATH=src python -m benchmarks.suites --run quick --out fresh-bench
    PYTHONPATH=src python -m benchmarks.suites --run full  --out trend-bench

so adding a benchmark is one registry entry, not four hand-duplicated
workflow steps. ``bench_gate`` imports the same table and fails when a
registered gated suite has no committed quick baseline — a suite cannot
silently run ungated.

Each suite module owns its own semantics (self-gates print ``... GATE
FAILED`` to stderr and exit non-zero); this runner only sequences them
and stops at the first failure.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import subprocess
import sys
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Suite:
    """One registered benchmark.

    ``artifact`` is the ``BENCH_*.json`` filename the module writes via
    ``--out`` (it does not always match the module name: the roofline
    suite emits ``BENCH_beam_kernel.json``). ``extra_args`` are appended
    in both quick and full modes. ``gated=True`` means bench_gate diffs
    the quick artifact against ``benchmarks/baselines/<artifact>``.
    """

    name: str
    module: str
    artifact: str
    title: str
    extra_args: Tuple[str, ...] = ()
    gated: bool = True


SUITES: Tuple[Suite, ...] = (
    Suite("fig7_throughput", "benchmarks.fig7_throughput",
          "BENCH_fig7_throughput.json",
          "fig7 throughput (QPS vs shard count)"),
    Suite("fig12_straggler", "benchmarks.fig12_straggler",
          "BENCH_fig12_straggler.json",
          "fig12 straggler robustness (scripted FaultSchedule)"),
    Suite("fig13_failure", "benchmarks.fig13_failure",
          "BENCH_fig13_failure.json",
          "fig13 failure recovery (scripted FaultSchedule)"),
    Suite("bench_build", "benchmarks.bench_build", "BENCH_build.json",
          "build subsystem + determinism gate",
          extra_args=("--workers", "4", "--check-determinism")),
    Suite("bench_quant", "benchmarks.bench_quant", "BENCH_quant.json",
          "quantized arena (recall/QPS/bytes, 3 metrics)"),
    Suite("bench_decode_stream", "benchmarks.bench_decode_stream",
          "BENCH_decode_stream.json",
          "streaming decode (tokens/s + per-token kNN hit parity)"),
    Suite("roofline", "benchmarks.roofline", "BENCH_beam_kernel.json",
          "kernel roofline (fused beam search vs loop path)"),
    Suite("bench_compaction", "benchmarks.bench_compaction",
          "BENCH_compaction.json",
          "compaction under load (QPS/p99/recall, on vs off)"),
    Suite("bench_multitenant", "benchmarks.bench_multitenant",
          "BENCH_multitenant.json",
          "multi-tenant isolation + filtered-search recall"),
)


def get(name: str) -> Suite:
    for s in SUITES:
        if s.name == name:
            return s
    raise KeyError(f"unknown benchmark suite {name!r}; "
                   f"registered: {[s.name for s in SUITES]}")


def gated_suites() -> Tuple[Suite, ...]:
    return tuple(s for s in SUITES if s.gated)


def command(suite: Suite, *, quick: bool, out_dir: str) -> list:
    """The exact argv the CI step for ``suite`` runs."""
    cmd = [sys.executable, "-m", suite.module]
    if quick:
        cmd.append("--quick")
    cmd += list(suite.extra_args)
    cmd += ["--out", os.path.join(out_dir, suite.artifact)]
    return cmd


def run_suite(suite: Suite, *, quick: bool, out_dir: str) -> int:
    cmd = command(suite, quick=quick, out_dir=out_dir)
    print(f"[suites] {suite.name}: {' '.join(cmd)}", file=sys.stderr)
    return subprocess.call(cmd)


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        description="run the registered benchmark suites")
    ap.add_argument("--run", choices=("quick", "full"),
                    help="execute every registered suite at this scale")
    ap.add_argument("--out", default="fresh-bench", metavar="DIR",
                    help="artifact directory (BENCH_*.json per suite)")
    ap.add_argument("--only", action="append", default=None,
                    metavar="NAME", help="restrict to named suite(s); "
                    "repeatable")
    ap.add_argument("--list", action="store_true",
                    help="print the registry and exit")
    args = ap.parse_args(argv)

    selected = (tuple(get(n) for n in args.only) if args.only
                else SUITES)
    if args.list or not args.run:
        for s in selected:
            gate = "gated" if s.gated else "ungated"
            extra = f" {' '.join(s.extra_args)}" if s.extra_args else ""
            print(f"{s.name:22s} {s.artifact:30s} [{gate}]{extra}"
                  f"  - {s.title}")
        return

    failures = []
    for s in selected:
        rc = run_suite(s, quick=args.run == "quick", out_dir=args.out)
        if rc != 0:
            failures.append((s.name, rc))
            print(f"[suites] {s.name} FAILED (exit {rc})",
                  file=sys.stderr)
            break   # fail fast: later artifacts would mask the failure
    if failures:
        sys.exit(1)
    print(f"[suites] {len(selected)} suites completed -> {args.out}/",
          file=sys.stderr)


if __name__ == "__main__":
    main()
