"""Quantized-arena benchmark: float32 vs int8 recall / QPS / bytes.

For each metric (l2, angular, ip) builds one index and measures, on the
fused single-host pipeline (``search_single_host``):

  * recall@10 of the float32 path and of the int8 path (asymmetric
    quantized beam search + exact float32 rerank of the top
    ``rerank_factor * k`` candidates);
  * steady-state QPS of both paths (best of ``repeats`` timed passes
    over the query batch, jit-warm);
  * arena bytes: the vector payload (what quantization compresses —
    float32 data vs int8 codes + the [w, d] scale/zero grid) and the
    total arena including the shared adjacency/ids arrays.

Writes one JSON row per metric to ``BENCH_quant.json``; CI's bench-gate
diffs the recall/QPS numbers of a fresh ``--quick`` run against the
committed ``benchmarks/baselines/`` copies.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks import common as C
from repro.common.config import PyramidConfig
from repro.core import metrics as M
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
from repro.data.synthetic import (clustered_vectors, norm_spread_vectors,
                                  query_set)
from repro.kernels.quant_distance import quant_impl

RERANK_FACTOR = 4


def _workload(metric: str, n: int, d: int, q: int) -> C.Workload:
    if metric == "ip":
        x = norm_spread_vectors(n, d, C.N_CLUSTERS, seed=2)
        queries = np.random.default_rng(3).normal(
            size=(q, d)).astype(np.float32)
    else:
        x = clustered_vectors(n, d, C.N_CLUSTERS, seed=0)
        queries = query_set(x, q, seed=1)
    xn = M.preprocess_dataset(x, metric)
    qn = M.preprocess_queries(queries, metric)
    true_ids, _ = M.brute_force_topk(qn, xn, C.TOPK, metric)
    return C.Workload(x, queries, true_ids, metric)


def _recall(ids, true_ids) -> float:
    return sum(
        len(set(np.asarray(a).tolist()) & set(b.tolist()))
        for a, b in zip(ids, true_ids)) / true_ids.size


def _timed_qps(index, queries, *, quantize: bool, repeats: int) -> float:
    search_single_host(index, queries, k=C.TOPK, quantize=quantize,
                       rerank_factor=RERANK_FACTOR)   # warm the jit cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        search_single_host(index, queries, k=C.TOPK, quantize=quantize,
                           rerank_factor=RERANK_FACTOR)
        best = min(best, time.perf_counter() - t0)
    return len(queries) / best


def run(quick: bool = False, n: int | None = None,
        d: int | None = None) -> list:
    n = n or (3_000 if quick else C.N_ITEMS)
    d = d or C.N_DIM
    q = 64 if quick else C.N_QUERIES
    shards = 4 if quick else C.NUM_SHARDS
    repeats = 3
    rows = []
    for metric in ("l2", "angular", "ip"):
        w = _workload(metric, n, d, q)
        cfg = PyramidConfig(
            metric=metric, num_shards=shards,
            meta_size=min(C.META_SIZE, max(shards, n // 16)),
            sample_size=min(n, 8_000), branching_factor=2,
            max_degree=16, max_degree_upper=8, ef_construction=60,
            ef_search=80, kmeans_iters=8,
            replication_r=40 if metric == "ip" else 0, seed=0)
        index = build_pyramid_index(w.x, cfg)

        ids_f, _, _ = search_single_host(index, w.queries, k=C.TOPK)
        recall_f = _recall(ids_f, w.true_ids)
        qps_f = _timed_qps(index, w.queries, quantize=False,
                           repeats=repeats)

        ids_q, _, _ = search_single_host(
            index, w.queries, k=C.TOPK, quantize=True,
            rerank_factor=RERANK_FACTOR)
        recall_q = _recall(ids_q, w.true_ids)
        qps_q = _timed_qps(index, w.queries, quantize=True,
                           repeats=repeats)

        af = index.arena("float32")
        aq = index.arena("int8")
        row = {
            "metric": metric, "n": n, "d": d, "shards": shards,
            "k": C.TOPK, "rerank_factor": RERANK_FACTOR,
            "recall_at_10_float32": round(recall_f, 4),
            "recall_at_10_int8": round(recall_q, 4),
            "recall_drop": round(recall_f - recall_q, 4),
            "qps_float32": round(qps_f, 1),
            "qps_int8": round(qps_q, 1),
            "vector_bytes_float32": af.vector_nbytes,
            "vector_bytes_int8": aq.vector_nbytes,
            "vector_reduction": round(
                af.vector_nbytes / aq.vector_nbytes, 2),
            "arena_total_bytes_float32": af.total_nbytes,
            "arena_total_bytes_int8": aq.total_nbytes,
        }
        rows.append(row)
        C.emit(f"quant_{metric}_int8", 1e6 * q / row["qps_int8"],
               f"recall={row['recall_at_10_int8']} "
               f"(float {row['recall_at_10_float32']}), "
               f"{row['vector_reduction']}x smaller vectors")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--d", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = run(quick=args.quick, n=args.n, d=args.d)
    payload = {"quick": args.quick, "impl": quant_impl(), "rows": rows}
    C.write_bench(args.out, "quant", payload)
    payload = {"figure": "quant", **payload}
    json.dump(payload, sys.stdout, indent=2)
    print()
    worst_drop = max(r["recall_drop"] for r in rows)
    worst_red = min(r["vector_reduction"] for r in rows)
    if worst_drop > 0.01 or worst_red < 3.0:
        print(f"QUANT GATE FAILED: recall drop {worst_drop} (max 0.01) "
              f"/ vector reduction {worst_red}x (min 3x)",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
