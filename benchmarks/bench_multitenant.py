"""Multi-tenant + filtered-search benchmark.

Two sections, one JSON artifact (``BENCH_multitenant.json``):

**Filtered kNN vs post-filtering** — one index whose items carry tag
bitsets at several planted selectivities (0.05 .. 1.0). For each
selectivity the fused pipeline runs with ``filter_tags`` (alive-mask on
device + candidate-budget inflation, see ``repro.core.filters``) and is
scored against the brute-force ground truth *over the alive subset*;
the naive baseline runs the same search unfiltered and drops dead ids
afterwards. Self-gate: at selectivity <= 0.2 the filtered path must
beat post-filtering on recall@10 — that is the whole point of masking
pre-merge instead of dropping post-merge (a post-filtered top-10
contains ~selectivity x 10 alive items, so its recall collapses
linearly while the filtered path holds).

**Tenant isolation** — two tenants admitted into one
:class:`~repro.serving.tenancy.TenantManager` budget, each measured
solo then concurrently (two submitter threads released by a barrier):
per-tenant QPS and recall@10 under contention. A second manager with a
budget that fits only ONE tenant exercises the LRU evict / lazy re-pin
cycle and self-gates on the re-pinned tenant returning bit-identical
ids (eviction must never lose or reorder data).

CI's bench-gate diffs the recall/QPS leaves of a fresh ``--quick`` run
against ``benchmarks/baselines/BENCH_multitenant.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np

from benchmarks import common as C
from repro.common.config import PyramidConfig
from repro.core import filters as F
from repro.core import metrics as M
from repro.core.distributed import search_single_host
from repro.core.meta_index import build_pyramid_index
from repro.core.updates import set_item_tags
from repro.data.synthetic import clustered_vectors, query_set
from repro.serving.tenancy import TenantManager, estimate_arena_bytes

# one tag bit per planted selectivity: bit j is set on ~SELECTIVITIES[j]
# of the items, so a single index serves every filter width
SELECTIVITIES = (0.05, 0.1, 0.2, 0.5, 1.0)
REPEATS = 3


def _build(x: np.ndarray, shards: int, seed: int):
    n = len(x)
    cfg = PyramidConfig(
        metric="l2", num_shards=shards,
        meta_size=min(C.META_SIZE, max(shards, n // 16)),
        sample_size=min(n, 8_000), branching_factor=2, max_degree=16,
        max_degree_upper=8, ef_construction=60, ef_search=80,
        kmeans_iters=8, seed=seed)
    return build_pyramid_index(x, cfg)


def _plant_tags(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    tags = np.zeros(n, np.int64)
    for j, s in enumerate(SELECTIVITIES):
        tags |= np.where(rng.random(n) < s, np.int64(1 << j),
                         np.int64(0))
    return tags


def _filtered_truth(xn, qn, alive, k, metric):
    """Brute-force top-k over the alive subset, in global ids."""
    sub = np.where(alive)[0]
    tids, _ = M.brute_force_topk(qn, xn[sub], k, metric)
    return sub[tids]


def _recall(ids, true_ids) -> float:
    hits = sum(len(set(np.asarray(a).tolist()) & set(b.tolist()))
               for a, b in zip(ids, true_ids))
    return hits / true_ids.size


def run_filtered(quick: bool, n: int, d: int, q: int,
                 shards: int) -> list:
    x = clustered_vectors(n, d, C.N_CLUSTERS, seed=0)
    queries = query_set(x, q, seed=1)
    index = _build(x, shards, seed=0)
    tags = _plant_tags(n, seed=7)
    set_item_tags(index, np.arange(n), tags)
    xn = M.preprocess_dataset(x, "l2")
    qn = M.preprocess_queries(queries, "l2")

    # the post-filter baseline: ONE unfiltered search, dead ids dropped
    ids_u, _, _ = search_single_host(index, queries, k=C.TOPK)
    ids_u = np.asarray(ids_u)

    rows = []
    for j, s in enumerate(SELECTIVITIES):
        f = np.int64(1 << j)
        alive = F.alive_np(tags, f)
        sel = float(alive.mean())
        true_ids = _filtered_truth(xn, qn, alive, C.TOPK, "l2")

        ids_f, _, _ = search_single_host(index, queries, k=C.TOPK,
                                         filter_tags=f)
        best = float("inf")
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            search_single_host(index, queries, k=C.TOPK, filter_tags=f)
            best = min(best, time.perf_counter() - t0)

        alive_set = set(np.where(alive)[0].tolist())
        post = [[i for i in row.tolist() if i in alive_set]
                for row in ids_u]

        row = {
            "selectivity": round(sel, 4), "nominal": s,
            "filter_bit": j, "k": C.TOPK, "n": n,
            "inflation": F.inflation(sel),
            "recall_at_10_filtered": round(_recall(ids_f, true_ids), 4),
            "recall_at_10_postfilter": round(
                _recall(post, true_ids), 4),
            "qps_filtered": round(q / best, 1),
        }
        rows.append(row)
        C.emit(f"filtered_sel{s}", 1e6 * q / row["qps_filtered"],
               f"recall={row['recall_at_10_filtered']} vs "
               f"postfilter={row['recall_at_10_postfilter']} "
               f"(x{row['inflation']} budget)")
    return rows


def _timed_pass(client, queries, k, repeats,
                barrier: threading.Barrier | None = None):
    """Best-of-``repeats`` QPS over the batch + last pass's results
    (with the query_id -> ground-truth-row map recall scoring needs)."""
    futs = client.search_batch(queries, k=k)   # warm executors + jit
    C.gather(futs, 120.0)
    if barrier is not None:
        barrier.wait()
    best, scored = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        futs = client.search_batch(queries, k=k)
        results, timed_out = C.gather(futs, 120.0)
        best = min(best, time.perf_counter() - t0)
        scored = (results, {f.query_id: i for i, f in enumerate(futs)},
                  timed_out)
    return len(queries) / best, scored


def run_tenancy(quick: bool, n: int, d: int, q: int,
                shards: int) -> dict:
    workloads, indexes = [], []
    for t, seed in (("a", 0), ("b", 5)):
        x = clustered_vectors(n, d, C.N_CLUSTERS, seed=seed)
        queries = query_set(x, q, seed=seed + 1)
        true_ids, _ = M.brute_force_topk(
            M.preprocess_queries(queries, "l2"),
            M.preprocess_dataset(x, "l2"), C.TOPK, "l2")
        workloads.append((t, queries, true_ids))
        indexes.append(_build(x, shards, seed=seed))
    est = [estimate_arena_bytes(ix) for ix in indexes]

    rows = [{"tenant": t} for t, _, _ in workloads]
    # both tenants resident: solo passes, then a barrier-released
    # concurrent pass (one submitter thread per tenant)
    with TenantManager(2 * sum(est)) as tm:
        clients = []
        for (t, queries, true_ids), ix, row in zip(
                workloads, indexes, rows):
            tm.create(t, ix)
            cl = tm.client(t)
            clients.append(cl)
            qps, (res, rmap, lost) = _timed_pass(cl, queries, C.TOPK,
                                                 REPEATS)
            row["qps_solo"] = round(qps, 1)
            row["recall_at_10_solo"] = round(
                C.recall_at_k(res, true_ids, rows=rmap), 4)
            row["timed_out_solo"] = lost

        barrier = threading.Barrier(len(clients))
        out = [None] * len(clients)

        def worker(i: int) -> None:
            _, queries, _ = workloads[i]
            out[i] = _timed_pass(clients[i], queries, C.TOPK, REPEATS,
                                 barrier=barrier)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(clients))]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for (t, _, true_ids), row, got in zip(workloads, rows, out):
            qps, (res, rmap, lost) = got
            row["qps_concurrent"] = round(qps, 1)
            row["recall_at_10_concurrent"] = round(
                C.recall_at_k(res, true_ids, rows=rmap), 4)
            row["timed_out_concurrent"] = lost
            C.emit(f"tenant_{t}_concurrent",
                   1e6 * q / max(row["qps_concurrent"], 1e-9),
                   f"solo {row['qps_solo']} qps, "
                   f"recall={row['recall_at_10_concurrent']}")

    # evict / re-pin cycle: a budget that fits only one tenant at a
    # time; results before and after the round-trip must be identical
    (ta, qa, _), (tb, qb, _) = workloads
    with TenantManager(int(max(est) * 1.25)) as tm:
        tm.create(ta, indexes[0])
        ids0, _ = _gather_ids(tm.client(ta), qa, C.TOPK)
        tm.create(tb, indexes[1])          # evicts a (LRU)
        _gather_ids(tm.client(tb), qb, C.TOPK)
        t0 = time.perf_counter()
        ids1, _ = _gather_ids(tm.client(ta), qa, C.TOPK)  # re-pin a
        repin_s = time.perf_counter() - t0
        stats = tm.stats()
    eviction = {
        "repin_identical": bool(np.array_equal(ids0, ids1)),
        "repin_s": round(repin_s, 3),
        "evictions": {t: s["evictions"]
                      for t, s in stats["tenants"].items()},
    }
    return {"rows": rows, "eviction": eviction}


def _gather_ids(client, queries, k):
    futs = client.search_batch(queries, k=k)
    from repro.core.client import gather_arrays
    return gather_arrays(futs, k, 120.0)


def run(quick: bool = False) -> dict:
    n = 2_500 if quick else 10_000
    d = C.N_DIM
    q = 48 if quick else 128
    shards = 4 if quick else C.NUM_SHARDS
    return {
        "filtered": run_filtered(quick, n, d, q, shards),
        "tenancy": run_tenancy(quick, n, d, q, shards),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    sections = run(quick=args.quick)
    payload = {"quick": args.quick, **sections}
    C.write_bench(args.out, "multitenant", payload)
    json.dump({"figure": "multitenant", **payload}, sys.stdout,
              indent=2)
    print()

    failures = []
    for row in sections["filtered"]:
        if (row["nominal"] <= 0.2
                and row["recall_at_10_filtered"]
                <= row["recall_at_10_postfilter"]):
            failures.append(
                f"selectivity {row['nominal']}: filtered recall "
                f"{row['recall_at_10_filtered']} does not beat "
                f"post-filtering {row['recall_at_10_postfilter']}")
    ev = sections["tenancy"]["eviction"]
    if not ev["repin_identical"]:
        failures.append(
            "evict/re-pin round-trip changed search results")
    if failures:
        print("MULTITENANT GATE FAILED: " + "; ".join(failures),
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
