"""Build-subsystem benchmark: parallel vs sequential construction, plus
store publish/load costs (paper Sec. IV-A GraphConstructor / Fig. 14
flavour — the figures the query-side benches don't cover).

Measures, at ``--n 20000 --shards 8`` by default:

  * sequential sub-HNSW build wall-clock (the seed-era path);
  * parallel build wall-clock with a ``--workers`` process pool;
  * the *determinism gate*: both builds are published to temp stores and
    their manifest shard checksums compared — the parallel fan-out must
    be bit-identical to the sequential loop (``--check-determinism``
    exits non-zero on mismatch, which is what CI runs);
  * store publish time, full load time, single-shard lazy load time, and
    on-disk size.

``--out`` writes one JSON row per configuration to ``BENCH_build.json``.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

from benchmarks import common as C
from repro.build import build_pyramid_index_parallel
from repro.common.config import PyramidConfig
from repro.store import IndexStore


def _cfg(w, *, num_shards: int) -> PyramidConfig:
    return PyramidConfig(
        metric=w.metric, num_shards=num_shards,
        meta_size=min(C.META_SIZE, max(num_shards, len(w.x) // 16)),
        sample_size=min(len(w.x), 8_000), branching_factor=2,
        max_degree=16, max_degree_upper=8, ef_construction=60,
        ef_search=80, kmeans_iters=8, seed=0)


def _manifest_checksums(store: IndexStore, vid: str):
    m = store.reader(vid).manifest
    return ([s["checksum"] for s in m["shards"]], m["meta"]["checksum"])


def run(quick: bool = False, out: str | None = None, *,
        n: int | None = None, shards: int = 8, workers: int = 4,
        check_determinism: bool = False) -> list:
    n = n or (4_000 if quick else C.N_ITEMS)
    w = C.euclidean_workload(n=n)
    cfg = _cfg(w, num_shards=shards)

    t0 = time.perf_counter()
    idx_seq = build_pyramid_index_parallel(w.x, cfg, workers=0)
    seq_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    idx_par = build_pyramid_index_parallel(w.x, cfg, workers=workers)
    par_s = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as tmp:
        s_seq = IndexStore(f"{tmp}/seq")
        s_par = IndexStore(f"{tmp}/par")
        t0 = time.perf_counter()
        v_seq = s_seq.publish(idx_seq)
        publish_s = time.perf_counter() - t0
        v_par = s_par.publish(idx_par)
        seq_sums = _manifest_checksums(s_seq, v_seq)
        par_sums = _manifest_checksums(s_par, v_par)
        deterministic = seq_sums == par_sums
        t0 = time.perf_counter()
        s_seq.load()
        load_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        s_seq.reader().load_shard(0)
        load_shard_s = time.perf_counter() - t0
        store_bytes = s_seq.version_bytes(v_seq)

    sub_seq = idx_seq.build_stats["subgraphs_wall_s"]
    sub_par = idx_par.build_stats["subgraphs_wall_s"]
    row = {
        "n": n, "d": w.x.shape[1], "shards": shards, "workers": workers,
        "seq_build_s": round(seq_s, 3),
        "par_build_s": round(par_s, 3),
        # headline speedup compares the sub-HNSW stage only: it is the
        # stage the pool parallelises AND it is jit-free — the total
        # wall-clocks include one-time kmeans/assignment compiles that
        # the second (parallel) build gets from a warm cache, which
        # would flatter the pool
        "speedup": round(sub_seq / max(sub_par, 1e-9), 3),
        "total_speedup": round(seq_s / max(par_s, 1e-9), 3),
        "shard_build_s": idx_par.build_stats["shard_build_s"],
        "subgraphs_seq_s": sub_seq,
        "subgraphs_par_s": sub_par,
        "build_retries": idx_par.build_stats["build_retries"],
        "publish_s": round(publish_s, 3),
        "load_s": round(load_s, 3),
        "load_shard_s": round(load_shard_s, 4),
        "store_bytes": store_bytes,
        "deterministic": bool(deterministic),
    }
    print(f"bench_build,n={n},shards={shards},workers={workers},"
          f"seq={row['seq_build_s']}s,par={row['par_build_s']}s,"
          f"speedup={row['speedup']}x,publish={row['publish_s']}s,"
          f"load={row['load_s']}s,deterministic={deterministic}")
    rows = [row]
    if out:
        with open(C.ensure_parent(out), "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {out}")
    if check_determinism and not deterministic:
        print("DETERMINISM GATE FAILED: parallel build checksums differ "
              "from sequential", file=sys.stderr)
        sys.exit(1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--out", default=None)
    ap.add_argument("--check-determinism", action="store_true",
                    help="exit non-zero unless parallel == sequential "
                         "manifest checksums")
    args = ap.parse_args()
    run(quick=args.quick, out=args.out, n=args.n, shards=args.shards,
        workers=args.workers, check_determinism=args.check_determinism)


if __name__ == "__main__":
    main()
