"""Shared fixtures for the paper-figure benchmarks.

Scales are CPU-laptop sized (the container has no TPU): the *shapes* of the
paper's curves are what we reproduce; EXPERIMENTS.md records the mapping to
the paper's cluster-scale numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.common.config import PyramidConfig
from repro.common.utils import nearest_rank
from repro.core import metrics as M
from repro.core.client import PyramidClient, SearchFuture
from repro.core.client import gather as client_gather
from repro.core.meta_index import PyramidIndex, build_pyramid_index
from repro.data.synthetic import (clustered_vectors, norm_spread_vectors,
                                  query_set)

# benchmark scale (override with --quick for CI-speed runs)
N_ITEMS = 20_000
N_DIM = 32
N_CLUSTERS = 64
N_QUERIES = 200
NUM_SHARDS = 8
META_SIZE = 256
TOPK = 10


@dataclasses.dataclass
class Workload:
    x: np.ndarray
    queries: np.ndarray
    true_ids: np.ndarray
    metric: str


_CACHE: Dict = {}


def euclidean_workload(n=N_ITEMS, d=N_DIM, q=N_QUERIES) -> Workload:
    key = ("euclid", n, d, q)
    if key not in _CACHE:
        x = clustered_vectors(n, d, N_CLUSTERS, seed=0)
        queries = query_set(x, q, seed=1)
        true_ids, _ = M.brute_force_topk(queries, x, TOPK, "l2")
        _CACHE[key] = Workload(x, queries, true_ids, "l2")
    return _CACHE[key]


def mips_workload(n=N_ITEMS, d=N_DIM, q=N_QUERIES) -> Workload:
    key = ("mips", n, d, q)
    if key not in _CACHE:
        x = norm_spread_vectors(n, d, N_CLUSTERS, seed=2)
        queries = np.random.default_rng(3).normal(
            size=(q, d)).astype(np.float32)
        true_ids, _ = M.brute_force_topk(queries, x, TOPK, "ip")
        _CACHE[key] = Workload(x, queries, true_ids, "ip")
    return _CACHE[key]


def build_index(w: Workload, *, num_shards=NUM_SHARDS, meta_size=META_SIZE,
                branching_factor=2, replication_r=0,
                seed=0) -> PyramidIndex:
    key = ("idx", id(w.x), num_shards, meta_size, replication_r, seed)
    if key not in _CACHE:
        cfg = PyramidConfig(
            metric=w.metric, num_shards=num_shards, meta_size=meta_size,
            sample_size=min(len(w.x), 8_000),
            branching_factor=branching_factor,
            max_degree=16, max_degree_upper=8, ef_construction=60,
            ef_search=80, replication_r=replication_r, kmeans_iters=8,
            seed=seed)
        _CACHE[key] = build_pyramid_index(w.x, cfg)
    return _CACHE[key]


def open_client(index: PyramidIndex, *, replicas: int = 1,
                **engine_kw) -> PyramidClient:
    """Spin up a ServingEngine for ``index`` and return a client session.
    Tear down with ``client.engine.shutdown()``."""
    return PyramidClient.from_index(index, replicas=replicas, **engine_kw)


def gather(futures: List[SearchFuture], timeout: float
           ) -> Tuple[list, int]:
    """Await a batch under one shared deadline.

    Returns ``(results, timed_out)`` — benchmark code counts stragglers
    instead of letting the per-query ``TimeoutError`` propagate.
    """
    got = client_gather(futures, timeout, return_exceptions=True)
    results = [r for r in got if not isinstance(r, Exception)]
    return results, len(got) - len(results)


def precision(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    hits = sum(len(set(f.tolist()) & set(t.tolist()))
               for f, t in zip(found_ids, true_ids))
    return hits / true_ids.size


def recall_at_k(results, true_ids: np.ndarray, *,
                rows: Optional[Dict[int, int]] = None) -> float:
    """recall@k over engine ``QueryResult`` rows.

    ``rows`` maps ``query_id -> true_ids row`` (build it from the
    submitted futures); without it results are scored positionally,
    which is only correct when *no* future timed out — :func:`gather`
    drops timeouts from the list, which would misalign every later
    result with the wrong ground-truth row.
    """
    if not len(results):
        return float("nan")
    hits = 0
    for i, r in enumerate(results):
        t = true_ids[rows[r.query_id] if rows is not None else i]
        hits += len(set(r.ids.tolist()) & set(t.tolist()))
    return hits / (len(results) * true_ids.shape[1])


def percentile(xs, q: float) -> float:
    """Exact q-th percentile (0..100) of a latency sample; nan if empty.
    Same nearest-rank definition the engine's LatencyTracker uses."""
    xs = sorted(xs)
    if not xs:
        return float("nan")
    return nearest_rank(xs, q)


def latency_summary(results) -> Dict[str, float]:
    """p50/p99/mean seconds over ``QueryResult.latency_s`` rows."""
    lats = [r.latency_s for r in results]
    return {"p50_s": percentile(lats, 50), "p99_s": percentile(lats, 99),
            "mean_s": float(np.mean(lats)) if lats else float("nan")}


def ensure_parent(path: str) -> str:
    """Create ``path``'s parent directory (CI writes artifacts into a
    fresh-bench/ dir the bench-gate then diffs against the committed
    baselines). Returns ``path`` so writers can inline it."""
    import os
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    return path


def write_bench(path: Optional[str], figure: str, payload: dict) -> None:
    """Write a ``BENCH_*.json`` artifact (CI uploads these to track the
    robustness/perf trajectory); no-op when ``path`` is falsy."""
    if not path:
        return
    import json
    with open(ensure_parent(path), "w") as f:
        json.dump({"figure": figure, **payload}, f, indent=2)


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV line per harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
