"""Paper Fig. 6: precision vs branching factor K (two meta sizes).
Expectation: precision rises quickly with K then saturates; smaller meta
size gives higher precision at equal K (more shards touched)."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.distributed import search_single_host


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    meta_sizes = (64, 256) if not quick else (32,)
    ks = (1, 2, 4, 8) if not quick else (1, 4)
    rows = []
    for m in meta_sizes:
        idx = C.build_index(w, meta_size=m)
        for k in ks:
            t0 = time.perf_counter()
            ids, _, mask = search_single_host(
                idx, w.queries, k=C.TOPK, branching_factor=k)
            dt = (time.perf_counter() - t0) / len(w.queries)
            p = C.precision(ids, w.true_ids)
            rows.append((m, k, p, mask.mean()))
            C.emit(f"fig6/precision/meta{m}/K{k}", dt * 1e6,
                   f"precision={p:.3f};access={mask.mean():.3f}")
    for m in meta_sizes:
        ps = [p for mm, k, p, _ in rows if mm == m]
        assert ps[-1] >= ps[0] - 0.02, f"precision should rise with K: {ps}"
    return rows


if __name__ == "__main__":
    run()
