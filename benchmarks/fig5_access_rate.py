"""Paper Fig. 5: sub-HNSW access rate vs branching factor K, for two
meta-HNSW sizes. Expectation: rate grows with K, shrinks with meta size."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.router import access_rate, route_queries


def run(quick: bool = False):
    w = C.euclidean_workload(n=4_000 if quick else C.N_ITEMS)
    meta_sizes = (64, 256) if not quick else (32, 64)
    ks = (1, 2, 4, 8) if not quick else (1, 4)
    rows = []
    for m in meta_sizes:
        idx = C.build_index(w, meta_size=m)
        meta = idx.meta_arrays()
        parts = jnp.asarray(idx.part_of_center)
        for k in ks:
            t0 = time.perf_counter()
            mask, _ = route_queries(
                meta, parts, jnp.asarray(w.queries), metric="l2",
                branching_factor=k, num_shards=idx.num_shards)
            rate = access_rate(mask)
            dt = (time.perf_counter() - t0) / len(w.queries)
            rows.append((m, k, rate))
            C.emit(f"fig5/access_rate/meta{m}/K{k}", dt * 1e6,
                   f"access_rate={rate:.3f}")
    # invariants from the paper
    by_m = {m: [r for mm, k, r in rows if mm == m] for m in meta_sizes}
    for m, rates in by_m.items():
        assert all(np.diff(rates) >= -1e-9), \
            f"access rate must grow with K (meta {m}): {rates}"
    return rows


if __name__ == "__main__":
    run()
