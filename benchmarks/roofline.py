"""Roofline table from the dry-run artifacts (deliverable (g)).

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and prints
per (arch x shape x mesh): the three roofline terms, the dominant one,
MODEL_FLOPS/HLO_FLOPS, and bytes/chip. Used to build EXPERIMENTS.md
§Roofline and to pick the three hillclimb pairs.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks import common as C

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")


def load(mesh: str = "pod") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = False):
    recs = load("pod")
    if not recs:
        C.emit("roofline/missing", 0.0,
               "no artifacts; run python -m repro.launch.dryrun first")
        return []
    rows = []
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r.get("skipped"):
            C.emit(name, 0.0, "skipped=" + r["skipped"][:40].replace(",", ";"))
            continue
        rf = r["roofline"]
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        frac = rf[rf["dominant"]] / total if total else 0.0
        C.emit(
            name, total * 1e6,
            f"dominant={rf['dominant']};compute_s={rf['compute_s']:.2e};"
            f"memory_s={rf['memory_s']:.2e};"
            f"collective_s={rf['collective_s']:.2e};"
            f"useful_ratio={r['useful_compute_ratio']:.2f};"
            f"peak_GiB={r['memory'].get('peak_bytes', 0)/2**30:.1f}")
        rows.append((r["arch"], r["shape"], rf["dominant"], frac))
    return rows


if __name__ == "__main__":
    run()
