"""Kernel roofline: measured achieved vs peak bytes/s and FLOP/s for the
three search kernels (``beam_search``, ``quant_distance``,
``merge_topk``), plus the legacy dry-run roofline table when its
artifacts exist.

Peaks are *calibrated live* on whatever backend runs the benchmark (a
large jitted matmul for FLOP/s, a large jitted read+write for bytes/s)
so "fraction of peak" always compares against what this machine can
actually sustain, not a datasheet number. Per kernel we time the real
entry point wall-clock and divide analytic op counts by it:

  * ``beam_search`` — the fused arena strategy (``shard_axis="kernel"``)
    against the retired while-loop strategies on the same routed
    workload. FLOPs/bytes come from the expansion counts the walk
    actually executed (``beam_search_stats``), so the numerator is the
    algorithm's minimal work, not an implementation's traffic.
  * ``quant_distance`` — the asymmetric int8 scan.
  * ``merge_topk`` — the dedup top-k merge.

Writes ``BENCH_beam_kernel.json``. The kernel section ALWAYS runs (the
old module silently no-opped without dry-run artifacts — bench-smoke now
always gets rows); a non-quick ``main()`` exits nonzero if the rows are
empty or the fused beam kernel fails to beat the while-loop path at the
largest config.

PYTHONPATH=src python -m benchmarks.roofline [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import hnsw as H
from repro.core import metrics as M
from repro.core.arena import arena_search
from repro.core.quant import QuantParams
from repro.core.router import route_queries
from repro.kernels.beam_search import beam_impl, beam_search_stats
from repro.kernels.merge_topk import merge_topk
from repro.kernels.quant_distance import quant_scores

ART = os.environ.get("DRYRUN_ART", "artifacts/dryrun")
TOPK = C.TOPK


# ---------------------------------------------------------------------------
# Timing + peak calibration
# ---------------------------------------------------------------------------


def _best_time(fn: Callable[[], None], iters: int = 3,
               warmup: int = 1) -> float:
    """Best-of-N wall-clock of ``fn`` (fn must block on its result)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate_peaks(quick: bool = False) -> Dict[str, float]:
    """Sustained peak FLOP/s (large f32 matmul) and bytes/s (large
    read+write) on the current backend."""
    m = 512 if quick else 1024
    a = jnp.asarray(np.random.default_rng(0).normal(
        size=(m, m)).astype(np.float32))
    mm = jax.jit(lambda x, y: x @ y)
    t = _best_time(lambda: jax.block_until_ready(mm(a, a)))
    flops_per_s = 2.0 * m ** 3 / t

    n = (16 if quick else 64) * 2 ** 20 // 4   # f32 elements
    buf = jnp.zeros((n,), jnp.float32)
    touch = jax.jit(lambda x: x + 1.0)         # read n + write n
    t = _best_time(lambda: jax.block_until_ready(touch(buf)))
    bytes_per_s = 2.0 * n * 4 / t
    return {"backend": jax.default_backend(),
            "flops_per_s": flops_per_s, "bytes_per_s": bytes_per_s}


def _achieved(flops: float, model_bytes: float, seconds: float,
              peaks: Dict[str, float]) -> Dict[str, float]:
    af = flops / seconds
    ab = model_bytes / seconds
    return {
        "wall_s": round(seconds, 6),
        "achieved_flops_per_s": round(af, 1),
        "achieved_bytes_per_s": round(ab, 1),
        "frac_peak_flops": round(af / peaks["flops_per_s"], 4),
        "frac_peak_bytes": round(ab / peaks["bytes_per_s"], 4),
    }


# ---------------------------------------------------------------------------
# beam_search — fused strategy vs the while-loop strategies
# ---------------------------------------------------------------------------


def _beam_rows(quick: bool, peaks: Dict[str, float]) -> List[Dict]:
    configs = [(2_000, 64)] if quick else [(8_000, 128), (20_000, 256)]
    ef, kb = 80, 2
    rows = []
    for n_items, batch in configs:
        w = C.euclidean_workload(n=n_items, q=batch)
        index = C.build_index(w)
        arena = index.arena()
        meta = index.meta_arrays()
        poc = jnp.asarray(index.part_of_center)
        q = jnp.asarray(M.preprocess_queries(w.queries[:batch], w.metric))
        mask, _ = route_queries(meta, poc, q, metric=w.metric,
                                branching_factor=kb,
                                num_shards=index.num_shards,
                                ef=max(64, kb))
        mask = jnp.asarray(mask)
        load = int(np.max(np.asarray(mask).sum(axis=0)))
        capacity = min(batch, max(32, -(-load // 32) * 32))

        def timed(ax):
            def call():
                ids, sc, _ = arena_search(
                    arena, meta, poc, q, metric=w.metric, k=TOPK, ef=ef,
                    branching_factor=kb, capacity=capacity, mask=mask,
                    shard_axis=ax)
                jax.block_until_ready((ids, sc))
                return ids
            t = _best_time(call)
            return t, call()

        # two retired baselines: "vmap" is THE while-loop path (the
        # per-query lax.while_loop batched over every routed row — what
        # the fused walk replaces op-for-op, and the gate's baseline);
        # "map" is the old sequential CPU special case, measured and
        # reported because its per-shard early termination keeps it
        # competitive on CPU (see API.md) — it is retired for strategy
        # unification, and it cannot map onto the Pallas kernel.
        t_fused, ids_fused = timed("kernel")
        t_loop, ids_loop = timed("vmap")
        t_map, _ = timed("map")
        rec = C.precision(np.asarray(ids_fused), w.true_ids[:batch])

        # analytic op counts from the expansions this workload executes:
        # the kernel-strategy prologue (queue drain + descend) feeds the
        # counting oracle the exact rows the timed call walked
        qidx = jax.vmap(lambda col: jnp.nonzero(
            col, size=capacity, fill_value=batch)[0])(mask.T)
        qs = q[jnp.clip(qidx, 0, batch - 1)]
        entries = jax.vmap(lambda sl, qrow: jax.vmap(
            lambda qv: H._greedy_descend(
                sl.as_graph(), qv, w.metric, max_steps=64))(qrow))(
                    arena, qs)
        _, _, iters = beam_search_stats(
            arena.data, arena.bottom, qs, entries, metric=w.metric,
            ef=max(ef, TOPK), max_iters=400)
        e_total = int(np.asarray(iters).sum())
        n_rows = int(qidx.size)
        d = int(arena.data.shape[2])
        m0 = int(arena.bottom.shape[2])
        efc = min(max(ef, TOPK), int(arena.data.shape[1]))
        # distances dominate: 2d FLOPs per scored row, m0 rows per
        # expansion plus one entry score per walk
        flops = 2.0 * d * (e_total * m0 + n_rows)
        # minimal data movement of the walk: adjacency row + vector rows
        # per expansion, plus queries in and the beam out
        model_bytes = (e_total * m0 * (4.0 + 4.0 * d)
                       + n_rows * (4.0 * d + 8.0 * efc))
        row = {
            "n_items": n_items, "batch": batch, "ef": ef,
            "capacity": capacity, "impl": beam_impl(),
            "expansions": e_total,
            "qps_fused": round(batch / t_fused, 1),
            "qps_loop": round(batch / t_loop, 1),
            "qps_map": round(batch / t_map, 1),
            "speedup_vs_loop": round(t_loop / t_fused, 3),
            "speedup_vs_map": round(t_map / t_fused, 3),
            "recall_at10": round(rec, 4),
            "flops": flops, "model_bytes": model_bytes,
            **_achieved(flops, model_bytes, t_fused, peaks),
        }
        rows.append(row)
        C.emit(f"kernel/beam_search/n{n_items}_b{batch}",
               1e6 * t_fused / batch,
               f"qps_fused={row['qps_fused']};qps_loop={row['qps_loop']};"
               f"qps_map={row['qps_map']};"
               f"speedup={row['speedup_vs_loop']};"
               f"frac_peak_flops={row['frac_peak_flops']};"
               f"frac_peak_bytes={row['frac_peak_bytes']}")
    return rows


# ---------------------------------------------------------------------------
# quant_distance + merge_topk
# ---------------------------------------------------------------------------


def _quant_rows(quick: bool, peaks: Dict[str, float]) -> List[Dict]:
    b, n = (64, 2_048) if quick else (256, 16_384)
    d = C.N_DIM
    rng = np.random.default_rng(4)
    x = rng.normal(size=(n, d)).astype(np.float32)
    params = QuantParams.from_data(x)
    codes = jnp.asarray(params.quantize(x))
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    scale, zero = jnp.asarray(params.scale), jnp.asarray(params.zero)

    t = _best_time(lambda: jax.block_until_ready(
        quant_scores(q, codes, scale, zero, metric="l2")))
    flops = 2.0 * b * n * d             # the b x n x d contraction
    model_bytes = n * d * 1.0 + b * d * 4.0 + b * n * 4.0 + 2 * d * 4.0
    row = {"b": b, "n": n, "d": d, "flops": flops,
           "model_bytes": model_bytes,
           **_achieved(flops, model_bytes, t, peaks)}
    C.emit(f"kernel/quant_distance/b{b}_n{n}", 1e6 * t,
           f"frac_peak_flops={row['frac_peak_flops']};"
           f"frac_peak_bytes={row['frac_peak_bytes']}")
    return [row]


def _merge_rows(quick: bool, peaks: Dict[str, float]) -> List[Dict]:
    b = 128 if quick else 1_024
    m = C.NUM_SHARDS * TOPK
    rng = np.random.default_rng(5)
    scores = rng.normal(size=(b, m)).astype(np.float32)
    ids = rng.integers(0, 5_000, size=(b, m)).astype(np.int32)
    ids[:, ::7] = -1
    scores[ids < 0] = -np.inf
    sj, ij = jnp.asarray(scores), jnp.asarray(ids)

    t = _best_time(lambda: jax.block_until_ready(
        merge_topk(sj, ij, k=TOPK)))
    flops = float(b * m * TOPK)         # k masked-argmax rounds over m
    model_bytes = b * (m * 8.0 + TOPK * 8.0)
    row = {"b": b, "m": m, "k": TOPK, "flops": flops,
           "model_bytes": model_bytes,
           **_achieved(flops, model_bytes, t, peaks)}
    C.emit(f"kernel/merge_topk/b{b}_m{m}", 1e6 * t,
           f"frac_peak_flops={row['frac_peak_flops']};"
           f"frac_peak_bytes={row['frac_peak_bytes']}")
    return [row]


# ---------------------------------------------------------------------------
# Legacy dry-run table (kept as a secondary section; never gates)
# ---------------------------------------------------------------------------


def _legacy_dryrun_rows() -> List:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "*__pod.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    if not recs:
        C.emit("roofline/missing", 0.0,
               "no dryrun artifacts; kernel section above still ran")
        return []
    rows = []
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r.get("skipped"):
            C.emit(name, 0.0,
                   "skipped=" + r["skipped"][:40].replace(",", ";"))
            continue
        rf = r["roofline"]
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        frac = rf[rf["dominant"]] / total if total else 0.0
        C.emit(
            name, total * 1e6,
            f"dominant={rf['dominant']};compute_s={rf['compute_s']:.2e};"
            f"memory_s={rf['memory_s']:.2e};"
            f"collective_s={rf['collective_s']:.2e};"
            f"useful_ratio={r['useful_compute_ratio']:.2f};"
            f"peak_GiB={r['memory'].get('peak_bytes', 0)/2**30:.1f}")
        rows.append((r["arch"], r["shape"], rf["dominant"], frac))
    return rows


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def run(quick: bool = False, out: Optional[str] = None) -> dict:
    peaks = calibrate_peaks(quick)
    C.emit("kernel/peaks", 0.0,
           f"backend={peaks['backend']};"
           f"flops_per_s={peaks['flops_per_s']:.3e};"
           f"bytes_per_s={peaks['bytes_per_s']:.3e}")
    kernels = {
        "beam_search": {"rows": _beam_rows(quick, peaks)},
        "quant_distance": {"rows": _quant_rows(quick, peaks)},
        "merge_topk": {"rows": _merge_rows(quick, peaks)},
    }
    big = kernels["beam_search"]["rows"][-1] if \
        kernels["beam_search"]["rows"] else None
    summary = {
        "largest_config": None if big is None else
        {"n_items": big["n_items"], "batch": big["batch"]},
        "speedup_largest": None if big is None else
        big["speedup_vs_loop"],
        "fused_beats_loop_largest":
        bool(big and big["speedup_vs_loop"] > 1.0),
    }
    payload = {"quick": quick, "peaks": peaks, "kernels": kernels,
               "summary": summary,
               "legacy_dryrun": _legacy_dryrun_rows()}
    C.write_bench(out, "beam_kernel", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    payload = run(quick=args.quick, out=args.out)
    json.dump({"figure": "beam_kernel", **payload}, sys.stdout, indent=2)
    print()
    if not args.quick:
        rows = payload["kernels"]["beam_search"]["rows"]
        if not rows:
            print("ROOFLINE GATE FAILED: no beam_search rows",
                  file=sys.stderr)
            sys.exit(1)
        if not payload["summary"]["fused_beats_loop_largest"]:
            print("ROOFLINE GATE FAILED: fused beam kernel speedup "
                  f"{payload['summary']['speedup_largest']} <= 1.0 at "
                  "the largest config", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
